//! Lightweight span tracing: scoped timers with nested parent ids, a
//! JSONL sink behind a runtime switch, and a bounded per-job trace store
//! the server's `TRACE <job-id>` verb reads from.
//!
//! [`span`] costs one atomic load plus a thread-local check when tracing
//! is off and no capture is active — no clock read, no allocation. When
//! on, each span gets a per-thread monotone id and the id of the
//! innermost enclosing span as its parent; on drop it is appended to the
//! active job capture (if any) and written as one JSONL line to the sink
//! (if configured):
//!
//! ```text
//! {"name":"cd_solve","id":3,"parent":1,"start_us":120,"dur_us":4512,"thread":"ThreadId(7)"}
//! ```
//!
//! `start_us` is measured from the first use of the tracing layer in the
//! process. The job pool wraps each job in [`begin_job_capture`] /
//! [`end_job_capture`] and files the result (plus the job's duality-gap
//! timeline) under its job id via [`store_job_trace`]; the store keeps
//! the most recent [`MAX_STORED_TRACES`] jobs.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Jobs retained by the per-job trace store.
pub const MAX_STORED_TRACES: usize = 64;

/// Spans retained per job capture (a runaway solve cannot grow unbounded).
pub const MAX_SPANS_PER_JOB: usize = 10_000;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Serializes tests (here and in the CLI) that flip the process-wide
/// `ENABLED` switch or attach/detach the JSONL sink.
#[cfg(test)]
pub(crate) static ENABLED_TEST_LOCK: Mutex<()> = Mutex::new(());

/// Switch span tracing on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// True when span tracing is on.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the tracing layer's first use — the shared
/// timebase for spans and bus events.
pub(crate) fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

fn sink() -> &'static Mutex<Option<BufWriter<File>>> {
    static SINK: OnceLock<Mutex<Option<BufWriter<File>>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

/// Append span events as JSONL to `path` and switch tracing on. Writes
/// are buffered; [`clear_json_sink`] flushes.
pub fn set_json_sink(path: &Path) -> std::io::Result<()> {
    let f = OpenOptions::new().create(true).append(true).open(path)?;
    *sink().lock().unwrap() = Some(BufWriter::new(f));
    set_enabled(true);
    Ok(())
}

/// Flush and detach the JSONL sink (tracing stays in whatever state it
/// was).
pub fn clear_json_sink() {
    let mut s = sink().lock().unwrap();
    if let Some(w) = s.as_mut() {
        let _ = w.flush();
    }
    *s = None;
}

/// One completed span.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// per-thread monotone id (1-based)
    pub id: u64,
    /// id of the innermost enclosing span, 0 for roots
    pub parent: u64,
    pub name: &'static str,
    /// microseconds since the tracing layer's first use
    pub start_us: u64,
    pub dur_us: u64,
}

/// One dynamic-screening checkpoint in a job's gap timeline.
#[derive(Clone, Debug)]
pub struct GapEvent {
    /// path step (grid point) the checkpoint ran in
    pub step: usize,
    /// solver epoch/iteration at the checkpoint
    pub epoch: usize,
    /// restricted duality gap at the checkpoint's dual point
    pub gap: f64,
    /// surviving active width after the checkpoint
    pub width: usize,
    /// features discarded at the checkpoint
    pub dropped: usize,
}

/// Everything `TRACE <job-id>` replays for one job.
#[derive(Clone, Debug, Default)]
pub struct JobTrace {
    pub spans: Vec<SpanEvent>,
    pub gaps: Vec<GapEvent>,
    /// closing duality gap per path step
    pub step_gaps: Vec<f64>,
}

struct Ctx {
    next_id: u64,
    stack: Vec<u64>,
    capture: Option<Vec<SpanEvent>>,
}

thread_local! {
    static CTX: RefCell<Ctx> = const {
        RefCell::new(Ctx { next_id: 1, stack: Vec::new(), capture: None })
    };
}

/// Start collecting this thread's spans for a job (pool worker scope).
pub fn begin_job_capture() {
    CTX.with(|c| c.borrow_mut().capture = Some(Vec::new()));
}

/// Stop collecting and return the spans gathered since
/// [`begin_job_capture`]; empty if no capture was active.
pub fn end_job_capture() -> Vec<SpanEvent> {
    CTX.with(|c| c.borrow_mut().capture.take().unwrap_or_default())
}

fn store() -> &'static Mutex<VecDeque<(u64, JobTrace)>> {
    static STORE: OnceLock<Mutex<VecDeque<(u64, JobTrace)>>> = OnceLock::new();
    STORE.get_or_init(|| Mutex::new(VecDeque::new()))
}

/// File a job's trace under its pool job id, evicting the oldest entry
/// past [`MAX_STORED_TRACES`].
pub fn store_job_trace(job: u64, trace: JobTrace) {
    let mut s = store().lock().unwrap();
    s.retain(|(id, _)| *id != job);
    if s.len() >= MAX_STORED_TRACES {
        s.pop_front();
    }
    s.push_back((job, trace));
}

/// The stored trace for a pool job id, if still retained.
pub fn job_trace(job: u64) -> Option<JobTrace> {
    store()
        .lock()
        .unwrap()
        .iter()
        .rev()
        .find(|(id, _)| *id == job)
        .map(|(_, t)| t.clone())
}

struct SpanInner {
    name: &'static str,
    id: u64,
    parent: u64,
    start: Instant,
    start_us: u64,
}

/// Scoped span timer; records on drop. Inert (`None` inner) when tracing
/// is off and no job capture is active on this thread.
pub struct Span {
    inner: Option<SpanInner>,
}

/// Open a span. Keep the returned guard alive for the timed scope:
/// `let _sp = obs::trace::span("cd_solve");`
pub fn span(name: &'static str) -> Span {
    let capturing = CTX.with(|c| c.borrow().capture.is_some());
    if !enabled() && !capturing {
        return Span { inner: None };
    }
    let start_us = epoch().elapsed().as_micros() as u64;
    let (id, parent) = CTX.with(|c| {
        let mut c = c.borrow_mut();
        let id = c.next_id;
        c.next_id += 1;
        let parent = c.stack.last().copied().unwrap_or(0);
        c.stack.push(id);
        (id, parent)
    });
    Span {
        inner: Some(SpanInner { name, id, parent, start: Instant::now(), start_us }),
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else { return };
        let dur_us = inner.start.elapsed().as_micros() as u64;
        let ev = SpanEvent {
            id: inner.id,
            parent: inner.parent,
            name: inner.name,
            start_us: inner.start_us,
            dur_us,
        };
        CTX.with(|c| {
            let mut c = c.borrow_mut();
            // guards may drop out of nesting order (`drop(outer)` while an
            // inner guard lives on): remove this span's id from wherever
            // it sits, or later spans inherit a stale parent
            if let Some(pos) = c.stack.iter().rposition(|&id| id == inner.id) {
                c.stack.remove(pos);
            }
            if let Some(cap) = c.capture.as_mut() {
                if cap.len() < MAX_SPANS_PER_JOB {
                    cap.push(ev.clone());
                }
            }
        });
        if enabled() {
            if let Some(f) = sink().lock().unwrap().as_mut() {
                let line = format!(
                    "{{\"name\":\"{}\",\"id\":{},\"parent\":{},\"start_us\":{},\"dur_us\":{},\"thread\":\"{:?}\"}}\n",
                    ev.name, ev.id, ev.parent, ev.start_us, ev.dur_us,
                    std::thread::current().id(),
                );
                if f.write_all(line.as_bytes()).is_ok() {
                    super::metrics::counter_add(
                        "sasvi_trace_sink_bytes_total",
                        line.len() as u64,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_inert() {
        let _guard = ENABLED_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        let sp = span("noop");
        assert!(sp.inner.is_none());
    }

    #[test]
    fn capture_collects_nested_spans_with_parent_ids() {
        begin_job_capture();
        {
            let _outer = span("outer");
            let _inner = span("inner");
        }
        {
            let _root = span("root2");
        }
        let events = end_job_capture();
        assert_eq!(events.len(), 3);
        // drop order: inner first, then outer, then root2
        let inner = &events[0];
        let outer = &events[1];
        let root2 = &events[2];
        assert_eq!(inner.name, "inner");
        assert_eq!(inner.parent, outer.id);
        assert_eq!(outer.parent, 0);
        assert_eq!(root2.parent, 0);
        assert!(end_job_capture().is_empty(), "capture already taken");
    }

    #[test]
    fn interleaved_guard_drops_keep_parent_attribution_clean() {
        begin_job_capture();
        let a = span("ileave_a");
        let b = span("ileave_b"); // nested under a
        drop(a); // out of nesting order: a closes while b lives on
        let c = span("ileave_c"); // innermost live span is b
        drop(c);
        drop(b);
        // with a's id scrubbed from the stack, a fresh span is a root
        {
            let _d = span("ileave_d");
        }
        let events = end_job_capture();
        assert_eq!(events.len(), 4);
        // drop order: a, c, b, d
        let (ea, ec, eb, ed) = (&events[0], &events[1], &events[2], &events[3]);
        assert_eq!(ea.name, "ileave_a");
        assert_eq!(eb.parent, ea.id, "b opened under a");
        assert_eq!(ec.parent, eb.id, "c must attach to b, the innermost live span");
        assert_eq!(ed.parent, 0, "stale ids must not linger on the stack");
    }

    #[test]
    fn job_store_is_bounded_and_replaces_duplicates() {
        for i in 0..(MAX_STORED_TRACES as u64 + 8) {
            store_job_trace(1_000_000 + i, JobTrace::default());
        }
        assert!(job_trace(1_000_000).is_none(), "oldest evicted");
        assert!(job_trace(1_000_000 + MAX_STORED_TRACES as u64 + 7).is_some());
        let t = JobTrace { step_gaps: vec![0.5], ..Default::default() };
        store_job_trace(2_000_000, JobTrace::default());
        store_job_trace(2_000_000, t);
        assert_eq!(job_trace(2_000_000).unwrap().step_gaps, vec![0.5]);
    }

    #[test]
    fn json_sink_writes_one_line_per_span() {
        let _guard = ENABLED_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "sasvi_trace_test_{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let m0 = super::super::metrics::snapshot();
        set_json_sink(&path).unwrap();
        {
            let _sp = span("sink_test");
        }
        // the write is buffered; clear_json_sink must flush it out
        clear_json_sink();
        set_enabled(false);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text
            .lines()
            .filter(|l| l.contains("\"name\":\"sink_test\""))
            .collect();
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("\"dur_us\":"));
        let delta = super::super::metrics::snapshot().delta_since(&m0);
        assert!(
            delta
                .counters
                .get("sasvi_trace_sink_bytes_total")
                .copied()
                .unwrap_or(0)
                >= lines[0].len() as u64,
            "sink byte counter must cover the written line"
        );
        let _ = std::fs::remove_file(&path);
    }
}
