//! Dynamic safe screening — re-screening *inside* the solver.
//!
//! The pathwise rules ([`super::sasvi`], [`super::safe`], [`super::dpp`])
//! screen once per grid point, from the dual optimum of the *previous*
//! grid point. But the paper's variational-inequality construction works
//! for **any** dual-feasible point, not just an optimal one — so the test
//! can be re-applied as the solver converges, with a dual point built from
//! the current residual. Each re-screen shrinks the surviving set further,
//! and later epochs touch only the survivors (Dynamic Sasvi, Yamada &
//! Yamada 2021; Gap Safe rules, Fercoq, Gramfort & Salmon 2015).
//!
//! ## The fused test
//!
//! At a checkpoint inside a solve at `lambda`, with surviving set `A`,
//! current iterate `beta` (supported on `A`) and residual `r = y - X beta`,
//! build the feasible dual point of the **restricted** problem by dual
//! scaling:
//!
//! ```text
//!   theta = r / max(lambda, ||X_A^T r||_inf)
//! ```
//!
//! Two regions then contain the restricted dual optimum `theta*`:
//!
//! * **VI ball** (the dynamic analogue of the paper's Theorem-2 ball):
//!   `theta*` is the projection of `y/lambda` onto the dual feasible set,
//!   so instantiating its variational inequality at the feasible `theta`
//!   gives `<theta* - y/lambda, theta - theta*> >= 0` — the ball with
//!   diameter `[theta, y/lambda]`. This is Eq. 28/29's closed form with
//!   `b = y/lambda - theta`. (The *half-space* of the pathwise Sasvi dome
//!   is **not** available here: it instantiates the VI *at* `theta1`,
//!   which requires `theta1` to be optimal — mid-solve it is not.)
//! * **Gap ball**: the dual objective is `lambda^2`-strongly concave, so
//!   `||theta* - theta|| <= sqrt(2 G) / lambda` with
//!   `G = P(beta) - D(theta)` the restricted duality gap.
//!
//! Feature `j in A` is discarded when the smaller of the two maxima of
//! `|<x_j, .>|` over these regions is `< 1 - SCREEN_EPS`.
//!
//! ## When is this safe?
//!
//! The test certifies `beta*_j = 0` for the optimum of the problem
//! **restricted to `A`**. If `A` itself came from safe screening (the
//! pathwise safe rules, or previous dynamic checkpoints — safety
//! composes), the restricted optimum extends to the full optimum by
//! zeros, so every dynamic discard is exact for the full problem. Under
//! the unsafe strong rule the discards are "restricted-safe" and the
//! coordinator's KKT correction re-admits any casualties, exactly as it
//! does for the rule's own mistakes.
//!
//! Everything here runs on the [`crate::linalg::par`] column-block pool
//! with block-ordered reductions, so checkpoint decisions — and therefore
//! the whole dynamic solve — are bit-identical at every thread count.
//!
//! ## The shared checkpoint
//!
//! [`rescreen`] is deliberately the *single* implementation of the
//! in-solver checkpoint: the dynamic solvers call it to shrink their
//! active sets, and the [`crate::solver::working_set`] outer loop calls
//! the very same function once per outer iteration — its `gap` is the
//! full-candidate-set convergence certificate, its survivors are the
//! prune, and the `|x_j^T r|` scores it leaves in the caller's scratch are
//! exactly the KKT expansion scores. One batched pass, three consumers;
//! the two subsystems can never drift apart.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use crate::linalg::{par, DesignMatrix};
use crate::SCREEN_EPS;

/// Default re-screen cadence (epochs / iterations between checkpoints).
pub const DEFAULT_RECHECK: usize = 5;

/// Knobs for dynamic screening inside the solvers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DynamicOptions {
    pub enabled: bool,
    /// Epochs (CD) / iterations (FISTA) between re-screens. An epoch-0
    /// checkpoint always runs when enabled (it screens with the warm-start
    /// residual — at `lambda >= lambda_max` it discards everything before
    /// the first sweep). `0` disables re-screening entirely: the solve
    /// degrades gracefully to the static solver instead of erroring.
    /// Huge values behave like "epoch-0 checkpoint only".
    pub recheck_every: usize,
}

impl Default for DynamicOptions {
    fn default() -> Self {
        Self::off()
    }
}

impl DynamicOptions {
    /// Dynamic screening off (the static baseline).
    pub fn off() -> Self {
        Self { enabled: false, recheck_every: DEFAULT_RECHECK }
    }

    /// Dynamic screening on, re-screening every `k` epochs.
    pub fn enabled_every(k: usize) -> Self {
        Self { enabled: true, recheck_every: k }
    }

    /// True when checkpoints will actually run.
    pub fn active(&self) -> bool {
        self.enabled && self.recheck_every > 0
    }
}

// ---------------------------------------------------------------------------
// process-wide default (the global CLI `--dynamic` flag / config / server)
// ---------------------------------------------------------------------------

static PROCESS_ENABLED: AtomicBool = AtomicBool::new(false);
static PROCESS_RECHECK: AtomicUsize = AtomicUsize::new(DEFAULT_RECHECK);

/// Set the process-wide dynamic-screening default. Consulted wherever path
/// options are built from user input (CLI commands, the server's `PATH`
/// jobs) — mirroring how [`crate::linalg::par::set_threads`] makes
/// `--threads` a global knob. Library callers that build a
/// [`crate::coordinator::PathOptions`] directly are unaffected
/// (`PathOptions::default()` stays static).
pub fn set_process_default(opts: DynamicOptions) {
    PROCESS_ENABLED.store(opts.enabled, Ordering::Relaxed);
    PROCESS_RECHECK.store(opts.recheck_every, Ordering::Relaxed);
}

/// The current process-wide dynamic-screening default.
pub fn process_default() -> DynamicOptions {
    DynamicOptions {
        enabled: PROCESS_ENABLED.load(Ordering::Relaxed),
        recheck_every: PROCESS_RECHECK.load(Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------------
// the checkpoint test
// ---------------------------------------------------------------------------

/// Outcome of one re-screen checkpoint.
#[derive(Clone, Debug)]
pub struct Rescreen {
    /// surviving column indices, in the order they appeared in `active`
    pub survivors: Vec<usize>,
    /// discarded column indices, in the order they appeared in `active`
    pub dropped: Vec<usize>,
    /// restricted duality gap at the constructed dual point
    pub gap: f64,
    /// `||X_A^T r||_inf` (the dual-scaling denominator candidate)
    pub infeas: f64,
}

/// Evaluate the fused VI-ball + gap-ball test over the surviving set.
///
/// * `xty[j]` = `<x_j, y>` and `col_norms_sq[j]` = `||x_j||^2`, indexable
///   by every `j` in `active`;
/// * `beta` must be supported on `active` and `resid = y - X beta`;
/// * `xt_r` is scratch of length `x.ncols()`; on return `xt_r[j]` holds
///   `<x_j, r>` for `j` in `active`.
///
/// Pure function of its inputs; parallel over column blocks with
/// block-ordered reductions (bit-identical at every thread count).
#[allow(clippy::too_many_arguments)]
pub fn rescreen(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    xty: &[f64],
    col_norms_sq: &[f64],
    active: &[usize],
    beta: &[f64],
    resid: &[f64],
    xt_r: &mut [f64],
) -> Rescreen {
    assert!(lambda > 0.0, "dynamic screening needs lambda > 0");
    assert_eq!(y.len(), x.nrows());
    assert_eq!(resid.len(), x.nrows());
    // statistics over the survivors only: O(nnz(A)), never O(nnz(X))
    x.t_matvec_subset(resid, active, xt_r);
    let s: &[f64] = xt_r;
    // block maxima folded in block order — reproduces the serial fold
    let infeas = par::max_abs_indexed(active, s);
    // restricted duality gap at (beta, theta), via the same shared
    // arithmetic the CD stopping criterion uses; note theta - y/lambda = -b,
    // so the gap computation also yields ||b||^2 for the VI ball below
    let l1: f64 = active.iter().map(|&j| beta[j].abs()).sum();
    let (gap, bnorm2, scale) = crate::solver::scaled_dual_gap(y, resid, lambda, infeas, l1);
    let radius = (2.0 * gap.max(0.0)).sqrt() / lambda;
    let bnorm = bnorm2.sqrt();
    let thr = 1.0 - SCREEN_EPS;

    // fused per-feature test; the shared partition harvest concatenates
    // per-block lists in block order, so the output order is deterministic
    let (survivors, dropped) = par::partition_indexed(active, |j| {
        let xt = s[j] * scale; // <x_j, theta>
        let xn = col_norms_sq[j].sqrt();
        let gap_bound = xt.abs() + xn * radius;
        let xjb = xty[j] / lambda - xt; // <x_j, b>, b = y/lambda - theta
        let up = xt + 0.5 * (xn * bnorm + xjb);
        let um = -xt + 0.5 * (xn * bnorm - xjb);
        gap_bound.min(up.max(um)) >= thr
    });
    crate::obs::metrics::counter_inc("sasvi_checkpoints_total");
    crate::obs::metrics::counter_add(
        "sasvi_checkpoint_dropped_total",
        dropped.len() as u64,
    );
    crate::obs::metrics::observe(
        "sasvi_checkpoint_gap",
        gap,
        crate::obs::metrics::GAP_BUCKETS,
    );
    crate::obs::metrics::gauge_set("sasvi_checkpoint_width", survivors.len() as f64);
    crate::obs::events::publish(|| crate::obs::events::EventKind::Checkpoint {
        workload: "lasso",
        penalty: "l1",
        gap,
        width: survivors.len(),
        dropped: dropped.len(),
    });
    Rescreen { survivors, dropped, gap, infeas }
}

/// The elastic-net twin of [`rescreen`]: the identical fused VI-ball +
/// gap-ball test evaluated in the augmented geometry of
/// `[X; sqrt(alpha) I]` / `[y; 0]` — correlations become
/// `<x_j, r> - alpha beta_j`, column norms gain `+ alpha`, and the gap /
/// ball distance run through [`crate::solver`]'s `scaled_dual_gap_en`
/// (note `<x'_j, y'> = <x_j, y>`: the augmented response tail is zero, so
/// `xty` is reused untouched). Safety composes exactly as for ℓ1: the
/// checkpoint certifies `beta*_j = 0` for the problem restricted to
/// `active`.
#[allow(clippy::too_many_arguments)]
pub fn rescreen_en(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    alpha: f64,
    xty: &[f64],
    col_norms_sq: &[f64],
    active: &[usize],
    beta: &[f64],
    resid: &[f64],
    xt_r: &mut [f64],
) -> Rescreen {
    assert!(lambda > 0.0, "dynamic screening needs lambda > 0");
    assert_eq!(y.len(), x.nrows());
    assert_eq!(resid.len(), x.nrows());
    x.t_matvec_subset(resid, active, xt_r);
    for &j in active {
        xt_r[j] -= alpha * beta[j];
    }
    let s: &[f64] = xt_r;
    let infeas = par::max_abs_indexed(active, s);
    let l1: f64 = active.iter().map(|&j| beta[j].abs()).sum();
    let l2sq: f64 = active.iter().map(|&j| beta[j] * beta[j]).sum();
    let (gap, bnorm2, scale) =
        crate::solver::scaled_dual_gap_en(y, resid, lambda, alpha, infeas, l1, l2sq);
    let radius = (2.0 * gap.max(0.0)).sqrt() / lambda;
    let bnorm = bnorm2.sqrt();
    let thr = 1.0 - SCREEN_EPS;

    let (survivors, dropped) = par::partition_indexed(active, |j| {
        let xt = s[j] * scale;
        let xn = (col_norms_sq[j] + alpha).sqrt();
        let gap_bound = xt.abs() + xn * radius;
        let xjb = xty[j] / lambda - xt;
        let up = xt + 0.5 * (xn * bnorm + xjb);
        let um = -xt + 0.5 * (xn * bnorm - xjb);
        gap_bound.min(up.max(um)) >= thr
    });
    crate::obs::metrics::counter_inc("sasvi_checkpoints_total");
    crate::obs::metrics::counter_add(
        "sasvi_checkpoint_dropped_total",
        dropped.len() as u64,
    );
    crate::obs::metrics::observe(
        "sasvi_checkpoint_gap",
        gap,
        crate::obs::metrics::GAP_BUCKETS,
    );
    crate::obs::metrics::gauge_set("sasvi_checkpoint_width", survivors.len() as f64);
    crate::obs::events::publish(|| crate::obs::events::EventKind::Checkpoint {
        workload: "lasso",
        penalty: "en",
        gap,
        width: survivors.len(),
        dropped: dropped.len(),
    });
    Rescreen { survivors, dropped, gap, infeas }
}

/// Outcome of one sparse-group-lasso checkpoint: screening happens at
/// group granularity, so survivors/dropped are **group** ids.
#[derive(Clone, Debug)]
pub struct GroupRescreen {
    pub survivor_groups: Vec<usize>,
    pub dropped_groups: Vec<usize>,
    /// restricted duality gap at the ε-norm-scaled dual point
    pub gap: f64,
    /// `Omega^D(X_A^T r)` over the active groups (the scaling denominator)
    pub infeas: f64,
}

/// Gap-safe group checkpoint for the sparse-group lasso
/// `0.5||y - X beta||^2 + lambda (tau ||beta||_1
/// + (1-tau) sum_g w_g ||beta_g||_2)` (Ndiaye et al., Gap Safe rules).
///
/// The dual point is the residual scaled by
/// `1 / max(lambda, Omega^D(X_A^T r))` with the SGL dual norm (per-group
/// ε-norm); the gap ball radius `sqrt(2 gap)/lambda` is the penalty-
/// independent strong-concavity bound. Group `g` is discarded when the
/// bound `u_j = |<x_j, theta>| + ||x_j|| R` on `|<x_j, theta*>|` certifies
/// a group dual norm below one: `||(u - tau thr)_+||_2 < (1-tau) w_g thr`
/// (equivalently ε-norm(u) < thr; for `tau = 1` the per-feature ℓ1 test
/// `max u_j < thr` is used). Group loops run serially in group order, so
/// decisions are bit-identical at every thread count (the `X_A^T r` pass
/// itself uses the deterministic block engine).
///
/// `active_features` must be exactly the concatenated ranges of
/// `active_groups` (the caller maintains both); `beta` is supported on the
/// active features and `resid = y - X beta`.
#[allow(clippy::too_many_arguments)]
pub fn rescreen_sgl(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    tau: f64,
    groups: crate::penalty::GroupSpec,
    active_groups: &[usize],
    active_features: &[usize],
    col_norms_sq: &[f64],
    beta: &[f64],
    resid: &[f64],
    xt_r: &mut [f64],
) -> GroupRescreen {
    assert!(lambda > 0.0, "dynamic screening needs lambda > 0");
    assert_eq!(y.len(), x.nrows());
    assert_eq!(resid.len(), x.nrows());
    let p = x.ncols();
    x.t_matvec_subset(resid, active_features, xt_r);
    let s: &[f64] = xt_r;
    // SGL dual norm over the active groups (serial, deterministic fold)
    let mut buf: Vec<f64> = Vec::with_capacity(groups.size);
    let mut infeas = 0.0f64;
    for &g in active_groups {
        let r = groups.range(g, p);
        buf.clear();
        buf.extend(s[r].iter().map(|v| v.abs()));
        let nu = crate::penalty::sgl_group_dual_norm(&mut buf, tau, groups.weight(g, p));
        infeas = infeas.max(nu);
    }
    // primal penalty over the active groups
    let mut l1 = 0.0f64;
    let mut gsum = 0.0f64;
    for &g in active_groups {
        let r = groups.range(g, p);
        let mut nrm2 = 0.0;
        for j in r {
            l1 += beta[j].abs();
            nrm2 += beta[j] * beta[j];
        }
        gsum += groups.weight(g, p) * nrm2.sqrt();
    }
    let denom = lambda.max(infeas);
    let scale = if denom > 0.0 { 1.0 / denom } else { 0.0 };
    let mut bnorm2 = 0.0;
    for (rv, yv) in resid.iter().zip(y.iter()) {
        let d = rv * scale - yv / lambda;
        bnorm2 += d * d;
    }
    let primal = 0.5 * crate::linalg::ops::nrm2sq(resid)
        + lambda * (tau * l1 + (1.0 - tau) * gsum);
    let dual = 0.5 * crate::linalg::ops::nrm2sq(y) - 0.5 * lambda * lambda * bnorm2;
    let gap = primal - dual;
    let radius = (2.0 * gap.max(0.0)).sqrt() / lambda;
    let thr = 1.0 - SCREEN_EPS;

    let mut survivor_groups = Vec::with_capacity(active_groups.len());
    let mut dropped_groups = Vec::new();
    let mut dropped_features = 0usize;
    for &g in active_groups {
        let r = groups.range(g, p);
        let keep = if tau >= 1.0 {
            r.clone().any(|j| {
                s[j].abs() * scale + col_norms_sq[j].sqrt() * radius >= thr
            })
        } else {
            let mut acc = 0.0f64;
            for j in r.clone() {
                let u = s[j].abs() * scale + col_norms_sq[j].sqrt() * radius;
                let t = (u - tau * thr).max(0.0);
                acc += t * t;
            }
            acc.sqrt() >= (1.0 - tau) * groups.weight(g, p) * thr
        };
        if keep {
            survivor_groups.push(g);
        } else {
            dropped_groups.push(g);
            dropped_features += r.len();
        }
    }
    crate::obs::metrics::counter_inc("sasvi_checkpoints_total");
    crate::obs::metrics::counter_add(
        "sasvi_checkpoint_dropped_total",
        dropped_features as u64,
    );
    crate::obs::metrics::observe(
        "sasvi_checkpoint_gap",
        gap,
        crate::obs::metrics::GAP_BUCKETS,
    );
    let width: usize = survivor_groups
        .iter()
        .map(|&g| groups.range(g, p).len())
        .sum();
    crate::obs::metrics::gauge_set("sasvi_checkpoint_width", width as f64);
    crate::obs::events::publish(|| crate::obs::events::EventKind::Checkpoint {
        workload: "lasso",
        penalty: "sgl",
        gap,
        width,
        dropped: dropped_features,
    });
    GroupRescreen { survivor_groups, dropped_groups, gap, infeas }
}

// ---------------------------------------------------------------------------
// per-solve trace (the observability the coordinator and benches consume)
// ---------------------------------------------------------------------------

/// One re-screen checkpoint inside a solve.
#[derive(Clone, Debug)]
pub struct DynamicEvent {
    /// epochs (CD) / iterations (FISTA) completed before this checkpoint
    pub epoch: usize,
    pub width_before: usize,
    pub width_after: usize,
    /// restricted duality gap at the checkpoint's dual point
    pub gap: f64,
    /// columns discarded at this checkpoint. Index space is the solver's:
    /// dataset-global for CD; the path coordinator remaps FISTA's
    /// submatrix-local indices to global via [`DynamicTrace::remap`].
    pub dropped: Vec<usize>,
}

/// The full re-screen history of one solve.
#[derive(Clone, Debug, Default)]
pub struct DynamicTrace {
    /// active width when the solve started
    pub initial_width: usize,
    pub events: Vec<DynamicEvent>,
}

impl DynamicTrace {
    pub fn new(initial_width: usize) -> Self {
        Self { initial_width, events: Vec::new() }
    }

    pub fn push_event(
        &mut self,
        epoch: usize,
        width_before: usize,
        width_after: usize,
        gap: f64,
        dropped: Vec<usize>,
    ) {
        self.events.push(DynamicEvent { epoch, width_before, width_after, gap, dropped });
    }

    /// Checkpoints run during the solve.
    pub fn rechecks(&self) -> usize {
        self.events.len()
    }

    /// Total discard events. Under a safe rule this equals the number of
    /// distinct features discarded; under the strong rule's KKT correction
    /// a re-admitted feature may be discarded again in a later re-solve,
    /// so events can exceed [`DynamicTrace::distinct_dropped`].
    pub fn dropped_total(&self) -> usize {
        self.events.iter().map(|e| e.dropped.len()).sum()
    }

    /// Distinct features discarded dynamically (what the step records and
    /// the server's rejection ratios report — never exceeds the starting
    /// width, even across KKT re-solves).
    pub fn distinct_dropped(&self) -> usize {
        let mut ids: Vec<usize> = self
            .events
            .iter()
            .flat_map(|e| e.dropped.iter().copied())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    /// Active width after the last checkpoint.
    pub fn final_width(&self) -> usize {
        self.events.last().map(|e| e.width_after).unwrap_or(self.initial_width)
    }

    /// Fraction of the starting width discarded dynamically (the dynamic
    /// analogue of the paper's Fig. 5 rejection ratio). Counts distinct
    /// features, so re-admission cycles cannot push it above 1.
    pub fn rejection_ratio(&self) -> f64 {
        if self.initial_width == 0 {
            0.0
        } else {
            self.distinct_dropped() as f64 / self.initial_width as f64
        }
    }

    /// Map solver-local dropped indices to another index space (used by
    /// the path coordinator: FISTA submatrix column -> dataset feature).
    pub fn remap(&mut self, ids: &[usize]) {
        for ev in self.events.iter_mut() {
            for j in ev.dropped.iter_mut() {
                *j = ids[*j];
            }
        }
    }

    /// Append another solve's events (a strong-rule correction re-solve),
    /// offsetting its epochs by `epoch_offset`. Width bookkeeping across
    /// re-admissions is approximate — the histogram is observability, not
    /// a correctness surface.
    pub fn absorb(&mut self, other: DynamicTrace, epoch_offset: usize) {
        for mut ev in other.events {
            ev.epoch += epoch_offset;
            self.events.push(ev);
        }
    }

    /// The epoch-width trajectory: `(width, epochs spent at that width)`
    /// segments, in order, covering `total_epochs` solver epochs.
    pub fn epochs_at_width(&self, total_epochs: usize) -> Vec<(usize, usize)> {
        fn push(segs: &mut Vec<(usize, usize)>, width: usize, epochs: usize) {
            if epochs == 0 {
                return;
            }
            if let Some(last) = segs.last_mut() {
                if last.0 == width {
                    last.1 += epochs;
                    return;
                }
            }
            segs.push((width, epochs));
        }
        let mut segs = Vec::new();
        let mut width = self.initial_width;
        let mut at = 0usize;
        for ev in &self.events {
            let e = ev.epoch.min(total_epochs);
            if e > at {
                push(&mut segs, width, e - at);
                at = e;
            }
            width = ev.width_after;
        }
        if total_epochs > at {
            push(&mut segs, width, total_epochs - at);
        }
        segs
    }

    /// Total `epochs x active-width` work of the solve — the quantity
    /// dynamic screening exists to reduce (`benches/dynamic.rs` compares
    /// it against the static solver's `epochs * kept`).
    pub fn solver_work(&self, total_epochs: usize) -> u64 {
        self.epochs_at_width(total_epochs)
            .into_iter()
            .map(|(w, e)| w as u64 * e as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::solver::cd::{solve_cd, CdOptions};

    fn tight() -> CdOptions {
        CdOptions { max_epochs: 30_000, tol: 1e-13, gap_tol: 1e-13, ..Default::default() }
    }

    fn exact(ds: &crate::data::Dataset, lam: f64) -> (Vec<f64>, Vec<f64>) {
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        solve_cd(&ds.x, &ds.y, lam, &active, &norms, &mut beta, &mut resid, &tight());
        (beta, resid)
    }

    #[test]
    fn rescreen_is_safe_at_a_near_optimal_point() {
        for seed in [2u64, 14] {
            let ds = SyntheticSpec { n: 30, p: 150, nnz: 10, ..Default::default() }
                .generate(seed);
            let pre = ds.precompute();
            let lam = 0.4 * pre.lambda_max;
            let (beta, resid) = exact(&ds, lam);
            let active: Vec<usize> = (0..ds.p()).collect();
            let mut scratch = vec![0.0; ds.p()];
            let rs = rescreen(
                &ds.x, &ds.y, lam, &pre.xty, &pre.col_norms_sq, &active, &beta,
                &resid, &mut scratch,
            );
            assert!(rs.gap >= -1e-9, "gap {}", rs.gap);
            assert!(!rs.dropped.is_empty(), "seed {seed}: nothing screened");
            for &j in &rs.dropped {
                assert!(
                    beta[j].abs() < 1e-10,
                    "seed {seed}: dropped active feature {j} (beta {})",
                    beta[j]
                );
            }
            // survivors + dropped partition the input set, order preserved
            let mut all: Vec<usize> = rs.survivors.clone();
            all.extend(&rs.dropped);
            all.sort_unstable();
            assert_eq!(all, active);
        }
    }

    #[test]
    fn rescreen_is_safe_mid_solve() {
        // stop CD early (a genuinely suboptimal iterate) and verify the
        // drops against the exact solution
        let ds = SyntheticSpec { n: 30, p: 200, nnz: 15, ..Default::default() }
            .generate(6);
        let pre = ds.precompute();
        let lam = 0.3 * pre.lambda_max;
        let active: Vec<usize> = (0..ds.p()).collect();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        let rough = CdOptions { max_epochs: 3, gap_check_every: 0, ..Default::default() };
        solve_cd(&ds.x, &ds.y, lam, &active, &pre.col_norms_sq, &mut beta,
                 &mut resid, &rough);
        let mut scratch = vec![0.0; ds.p()];
        let rs = rescreen(
            &ds.x, &ds.y, lam, &pre.xty, &pre.col_norms_sq, &active, &beta,
            &resid, &mut scratch,
        );
        let (beta_star, _) = exact(&ds, lam);
        for &j in &rs.dropped {
            assert!(beta_star[j].abs() < 1e-10, "feature {j}: {}", beta_star[j]);
        }
    }

    #[test]
    fn zero_residual_checkpoint_is_finite_and_safe() {
        // y = X beta0 exactly: the checkpoint sees r = 0, theta = 0
        let ds = SyntheticSpec { n: 20, p: 40, nnz: 4, ..Default::default() }
            .generate(8);
        let mut beta = vec![0.0; ds.p()];
        beta[3] = 1.5;
        beta[17] = -0.25;
        let mut y = vec![0.0; ds.n()];
        ds.x.matvec(&beta, &mut y);
        let mut xty = vec![0.0; ds.p()];
        ds.x.t_matvec(&y, &mut xty);
        let norms = ds.x.col_norms_sq();
        let resid = vec![0.0; ds.n()];
        let active: Vec<usize> = (0..ds.p()).collect();
        let mut scratch = vec![0.0; ds.p()];
        let rs = rescreen(&ds.x, &y, 0.5, &xty, &norms, &active, &beta, &resid,
                          &mut scratch);
        assert!(rs.gap.is_finite() && rs.gap >= 0.0, "gap {}", rs.gap);
        assert!(rs.infeas == 0.0);
        assert_eq!(rs.survivors.len() + rs.dropped.len(), ds.p());
    }

    #[test]
    fn empty_active_set_is_a_noop() {
        let ds = SyntheticSpec { n: 10, p: 20, nnz: 2, ..Default::default() }
            .generate(1);
        let pre = ds.precompute();
        let beta = vec![0.0; ds.p()];
        let mut scratch = vec![0.0; ds.p()];
        let rs = rescreen(
            &ds.x, &ds.y, 1.0, &pre.xty, &pre.col_norms_sq, &[], &beta, &ds.y,
            &mut scratch,
        );
        assert!(rs.survivors.is_empty() && rs.dropped.is_empty());
        assert!(rs.gap.is_finite());
    }

    #[test]
    fn options_and_process_default_round_trip() {
        let _guard = crate::linalg::par::test_knob_guard();
        let before = process_default();
        assert!(!DynamicOptions::off().active());
        assert!(DynamicOptions::enabled_every(3).active());
        assert!(!DynamicOptions { enabled: true, recheck_every: 0 }.active());
        set_process_default(DynamicOptions::enabled_every(7));
        assert_eq!(process_default(), DynamicOptions::enabled_every(7));
        set_process_default(before);
    }

    #[test]
    fn distinct_dropped_dedupes_readmission_cycles() {
        // a KKT-re-admitted feature discarded again must count once
        let mut t = DynamicTrace::new(10);
        t.push_event(0, 10, 8, 1.0, vec![3, 7]);
        t.push_event(4, 9, 8, 0.5, vec![7]); // 7 re-admitted then re-dropped
        assert_eq!(t.dropped_total(), 3);
        assert_eq!(t.distinct_dropped(), 2);
    }

    #[test]
    fn trace_histogram_and_work() {
        let mut t = DynamicTrace::new(100);
        t.push_event(0, 100, 80, 1.0, (80..100).collect());
        t.push_event(5, 80, 50, 0.1, (50..80).collect());
        assert_eq!(t.rechecks(), 2);
        assert_eq!(t.dropped_total(), 50);
        assert_eq!(t.final_width(), 50);
        assert!((t.rejection_ratio() - 0.5).abs() < 1e-15);
        // epochs 0..5 at width 80, 5..12 at width 50
        assert_eq!(t.epochs_at_width(12), vec![(80, 5), (50, 7)]);
        assert_eq!(t.solver_work(12), 80 * 5 + 50 * 7);
        // remap into another index space
        let ids: Vec<usize> = (0..100).map(|j| j + 1000).collect();
        t.remap(&ids);
        assert_eq!(t.events[0].dropped[0], 1080);
    }
}
