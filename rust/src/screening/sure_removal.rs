//! Feature sure-removal parameters — §4 / Theorem 4 of the paper.
//!
//! For each feature `j`, Theorem 4 characterizes the monotonicity of the
//! Sasvi bounds `u_j^+(lam2)` and `u_j^-(lam2)` on `(0, lam1]` in terms of
//! two auxiliary functions
//!
//!   f(lam) = <y/lam - theta1, a> / ||y/lam - theta1||   (strictly increasing)
//!   g(lam) = <y/lam - theta1, y> / ||y/lam - theta1||   (strictly decreasing)
//!
//! and their per-feature roots `lam_{2,a}` (f = <x_j,a>/||x_j||) and
//! `lam_{2,y}` (g = <x_j,y>/||x_j||). From the monotone structure we compute
//! the **sure removal parameter** `lam_s(j)`: the smallest value such that
//! feature j is screened for every `lam in (lam_s, lam1)` — i.e. the point
//! where following the path further might make the feature active.

use crate::linalg::ops;
use crate::screening::sasvi::feature_bounds;
use crate::screening::{Geometry, ScreenContext};
use crate::solver::DualState;
use crate::SCREEN_EPS;

/// Per-state scalars reused across features and lambda evaluations.
#[derive(Clone, Copy, Debug)]
pub struct SureRemovalAnalysis {
    pub lam1: f64,
    pub anorm2: f64,
    pub ay: f64,
    pub ynorm2: f64,
}

/// The per-feature report of the Theorem-4 analysis.
#[derive(Clone, Copy, Debug)]
pub struct FeatureRemoval {
    /// root lam_{2,a} (0 when f never reaches the target)
    pub lam_2a: f64,
    /// root lam_{2,y} (lam1 when g never reaches the target)
    pub lam_2y: f64,
    /// which Theorem-4 case applies: 1 (u- monotone via lam_2a <= lam_2y),
    /// 2 (same, by sign), or 3 (non-monotone bump on [lam_2y, lam_2a])
    pub case: u8,
    /// sure removal parameter: screened for all lam in (lam_s, lam1);
    /// equals lam1 when the feature cannot be screened even at lam1.
    pub lam_s: f64,
}

impl SureRemovalAnalysis {
    pub fn new(ctx: &ScreenContext, state: &DualState) -> Self {
        let lam1 = state.lambda;
        let ynorm2 = ctx.pre.y_norm_sq;
        let ty = ops::dot(&state.theta, ctx.y);
        let tnorm2 = ops::nrm2sq(&state.theta);
        let anorm2 = (ynorm2 / (lam1 * lam1) - 2.0 * ty / lam1 + tnorm2).max(0.0);
        let ay = ynorm2 / lam1 - ty;
        Self { lam1, anorm2, ay, ynorm2 }
    }

    /// gamma = 1/lam - 1/lam1 for lam in (0, lam1]
    #[inline]
    fn gamma(&self, lam: f64) -> f64 {
        1.0 / lam - 1.0 / self.lam1
    }

    /// f(lam) = <b, a>/||b|| with b = a + gamma y (Eq. 41).
    pub fn f(&self, lam: f64) -> f64 {
        let g = self.gamma(lam);
        let ba = self.anorm2 + g * self.ay;
        let bn2 = self.anorm2 + 2.0 * g * self.ay + g * g * self.ynorm2;
        ba / bn2.max(1e-300).sqrt()
    }

    /// g(lam) = <b, y>/||b|| (Eq. 42).
    pub fn g(&self, lam: f64) -> f64 {
        let g = self.gamma(lam);
        let by = self.ay + g * self.ynorm2;
        let bn2 = self.anorm2 + 2.0 * g * self.ay + g * g * self.ynorm2;
        by / bn2.max(1e-300).sqrt()
    }

    /// Root of a monotone function on `(lo, hi]` via bisection.
    fn bisect(&self, target: f64, increasing: bool, eval: impl Fn(f64) -> f64) -> f64 {
        let (mut lo, mut hi) = (1e-12 * self.lam1, self.lam1);
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            let v = eval(mid);
            let go_right = if increasing { v < target } else { v > target };
            if go_right {
                lo = mid;
            } else {
                hi = mid;
            }
            if hi - lo < 1e-14 * self.lam1 {
                break;
            }
        }
        0.5 * (lo + hi)
    }

    /// lam_{2,a} for a feature with <x_j, a> = xja >= 0, norm ||x_j||.
    pub fn lambda_2a(&self, xja: f64, xnorm: f64) -> f64 {
        if self.anorm2 <= 0.0 {
            return 0.0;
        }
        let target = xja / xnorm.max(1e-300);
        // f(0+) = <y,a>/||y||
        let f0 = self.ay / self.ynorm2.max(1e-300).sqrt();
        if f0 >= target {
            return 0.0;
        }
        self.bisect(target, true, |lam| self.f(lam))
    }

    /// lam_{2,y} for a feature with <x_j, y> = xjy, norm ||x_j||.
    pub fn lambda_2y(&self, xjy: f64, xnorm: f64) -> f64 {
        if self.anorm2 <= 0.0 {
            return self.lam1;
        }
        let target = xjy / xnorm.max(1e-300);
        // g(lam1) = <a,y>/||a||
        let g1 = self.ay / self.anorm2.sqrt();
        if g1 >= target {
            return self.lam1;
        }
        self.g_root(target)
    }

    fn g_root(&self, target: f64) -> f64 {
        self.bisect(target, false, |lam| self.g(lam))
    }

    /// Evaluate the Sasvi bounds for one feature at `lam2` in O(1).
    pub fn bounds_at(
        &self,
        lam2: f64,
        xt1: f64,
        xty: f64,
        xn2: f64,
    ) -> (f64, f64) {
        let g = Geometry::from_scalars(self.lam1, lam2, self.anorm2, self.ay, self.ynorm2);
        feature_bounds(&g, xt1, xty, xn2)
    }

    /// Full Theorem-4 report for feature j. `lam_min` bounds the search
    /// (the path never goes below it).
    pub fn analyze(
        &self,
        ctx: &ScreenContext,
        state: &DualState,
        j: usize,
        lam_min: f64,
    ) -> FeatureRemoval {
        let xt1 = state.xt_theta[j];
        let xty = ctx.pre.xty[j];
        let xn2 = ctx.pre.col_norms_sq[j];
        let xnorm = xn2.sqrt();
        // Theorem 4 assumes <x_j, a> >= 0; flip the feature otherwise.
        let xja = xty / self.lam1 - xt1;
        let (xt1s, xtys, xjas) = if xja >= 0.0 {
            (xt1, xty, xja)
        } else {
            (-xt1, -xty, -xja)
        };
        let lam_2a = self.lambda_2a(xjas, xnorm);
        let lam_2y = self.lambda_2y(xtys, xnorm);
        let case = if lam_2a <= lam_2y { 1 } else { 3 };
        let _ = xt1s;

        let lam_s = self.sure_removal_lambda(lam_min, xt1, xty, xn2);
        FeatureRemoval { lam_2a, lam_2y, case, lam_s }
    }

    /// Theorem-4 reports for *every* feature, evaluated in parallel column
    /// blocks on the [`crate::linalg::par`] pool. Each feature's scan
    /// (grid walk + bisections) is independent and costs far more than a
    /// dot product, so this is the best-scaling pass in the crate. Results
    /// are identical to calling [`SureRemovalAnalysis::analyze`] serially.
    pub fn analyze_all(
        &self,
        ctx: &ScreenContext,
        state: &DualState,
        lam_min: f64,
    ) -> Vec<FeatureRemoval> {
        // map_columns returns per-block Vecs in block order, so the
        // flattened result is in feature order — no unsafe scatter needed.
        crate::linalg::par::map_columns(ctx.p(), |_, r| {
            r.map(|j| self.analyze(ctx, state, j, lam_min))
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect()
    }

    /// Smallest `lam_s` such that `max(u^+, u^-) < 1` for every
    /// `lam in (lam_s, lam1)`; `lam1` if the feature is never screened.
    ///
    /// Robust to the case-3 non-monotone bump: scan a fine geometric grid
    /// downward from `lam1`, then bisect the bracketing interval.
    pub fn sure_removal_lambda(
        &self,
        lam_min: f64,
        xt1: f64,
        xty: f64,
        xn2: f64,
    ) -> f64 {
        let thr = 1.0 - SCREEN_EPS;
        let bound = |lam: f64| {
            let (up, um) = self.bounds_at(lam, xt1, xty, xn2);
            up.max(um)
        };
        // not screened arbitrarily close to lam1?
        if bound(self.lam1 * (1.0 - 1e-9)) >= thr {
            return self.lam1;
        }
        let lo = lam_min.max(1e-9 * self.lam1);
        let steps = 512;
        let ratio = (lo / self.lam1).powf(1.0 / steps as f64);
        let mut prev = self.lam1 * (1.0 - 1e-9);
        let mut lam = self.lam1 * ratio;
        for _ in 0..steps {
            if bound(lam) >= thr {
                // crossing in (lam, prev]; bisect
                let (mut a, mut b) = (lam, prev);
                for _ in 0..100 {
                    let mid = 0.5 * (a + b);
                    if bound(mid) >= thr {
                        a = mid;
                    } else {
                        b = mid;
                    }
                }
                return 0.5 * (a + b);
            }
            prev = lam;
            lam *= ratio;
            if lam < lo {
                break;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::solver::cd::{solve_cd, CdOptions};

    fn setup(seed: u64, frac: f64) -> (crate::data::Dataset, DualState) {
        let ds = SyntheticSpec { n: 30, p: 80, nnz: 8, ..Default::default() }
            .generate(seed);
        let lam1 = frac * ds.lambda_max();
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        solve_cd(&ds.x, &ds.y, lam1, &active, &norms, &mut beta, &mut resid,
                 &CdOptions::default());
        let st = DualState::from_residual(&ds.x, &resid, lam1);
        (ds, st)
    }

    #[test]
    fn f_is_increasing_g_is_decreasing() {
        let (ds, st) = setup(3, 0.6);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let a = SureRemovalAnalysis::new(&ctx, &st);
        let lams: Vec<f64> = (1..40).map(|i| st.lambda * i as f64 / 40.0).collect();
        for w in lams.windows(2) {
            assert!(a.f(w[0]) <= a.f(w[1]) + 1e-10, "f not increasing");
            assert!(a.g(w[0]) >= a.g(w[1]) - 1e-10, "g not decreasing");
        }
    }

    #[test]
    fn uplus_monotone_decreasing_in_lam2() {
        // Theorem 4, part 1.
        let (ds, st) = setup(5, 0.5);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let a = SureRemovalAnalysis::new(&ctx, &st);
        for j in (0..ds.p()).step_by(7) {
            let mut prev = f64::NEG_INFINITY;
            // decreasing lam2 -> u+ must increase
            for k in 1..30 {
                let lam2 = st.lambda * (1.0 - k as f64 / 31.0);
                let (up, _) = a.bounds_at(lam2, st.xt_theta[j], pre.xty[j],
                                          pre.col_norms_sq[j]);
                assert!(up >= prev - 1e-9, "j={j} lam2={lam2}");
                prev = up;
            }
        }
    }

    #[test]
    fn sure_removal_lambda_is_sound() {
        // For every feature, re-screening at any lam above lam_s must pass.
        let (ds, st) = setup(7, 0.7);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let a = SureRemovalAnalysis::new(&ctx, &st);
        let lam_min = 0.05 * pre.lambda_max;
        let mut screened_any = false;
        for j in 0..ds.p() {
            let rep = a.analyze(&ctx, &st, j, lam_min);
            assert!(rep.lam_s <= st.lambda + 1e-12);
            if rep.lam_s < st.lambda * 0.999 {
                screened_any = true;
                // sample a few lambdas strictly above lam_s
                for t in [0.2, 0.5, 0.9] {
                    let lam = rep.lam_s + (st.lambda * 0.999 - rep.lam_s) * t;
                    let (up, um) = a.bounds_at(lam, st.xt_theta[j], pre.xty[j],
                                               pre.col_norms_sq[j]);
                    assert!(
                        up.max(um) < 1.0,
                        "j={j} lam={lam} bound={} lam_s={}",
                        up.max(um),
                        rep.lam_s
                    );
                }
            }
        }
        assert!(screened_any, "expected some removable features");
    }

    #[test]
    fn roots_match_targets() {
        let (ds, st) = setup(11, 0.6);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let a = SureRemovalAnalysis::new(&ctx, &st);
        for j in (0..ds.p()).step_by(11) {
            let xn = pre.col_norms_sq[j].sqrt();
            let xja = (pre.xty[j] / st.lambda - st.xt_theta[j]).abs();
            let root = a.lambda_2a(xja, xn);
            if root > 0.0 && root < st.lambda * 0.999 {
                let v = a.f(root);
                assert!((v - xja / xn).abs() < 1e-6, "f(root)={v} target={}", xja / xn);
            }
        }
    }

    #[test]
    fn analyze_all_matches_serial_analyze() {
        let (ds, st) = setup(9, 0.65);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let a = SureRemovalAnalysis::new(&ctx, &st);
        let lam_min = 0.05 * st.lambda;
        let all = a.analyze_all(&ctx, &st, lam_min);
        assert_eq!(all.len(), ds.p());
        for (j, batch) in all.iter().enumerate() {
            let one = a.analyze(&ctx, &st, j, lam_min);
            assert_eq!(batch.lam_s.to_bits(), one.lam_s.to_bits(), "j={j}");
            assert_eq!(batch.lam_2a.to_bits(), one.lam_2a.to_bits(), "j={j}");
            assert_eq!(batch.lam_2y.to_bits(), one.lam_2y.to_bits(), "j={j}");
            assert_eq!(batch.case, one.case, "j={j}");
        }
    }

    #[test]
    fn case3_bump_detected_when_roots_cross() {
        // Construct case detection consistency: analyze() reports case 3
        // iff lam_2a > lam_2y; for such features u- must dip and rise.
        let (ds, st) = setup(13, 0.55);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let a = SureRemovalAnalysis::new(&ctx, &st);
        for j in 0..ds.p() {
            let rep = a.analyze(&ctx, &st, j, 0.01 * st.lambda);
            if rep.case == 3 {
                assert!(rep.lam_2a > rep.lam_2y);
                return; // found at least one; structure verified
            }
        }
        // not all instances produce case 3 — acceptable
    }
}
