//! Sasvi — the paper's screening rule (Theorem 3).
//!
//! The feasible set for the unknown dual optimum `theta_2^*` is built from
//! the two variational inequalities (Eqs. 13–14):
//!
//!   Omega = { theta : <theta1 - y/lam1, theta - theta1> >= 0,
//!                     <theta - y/lam2, theta1 - theta>  >= 0 }
//!
//! a half-space through `theta1` with inward normal `-a` intersected with
//! the ball of diameter `[theta1, y/lam2]`. Theorem 3 gives the closed-form
//! maxima `u_j^+ = max <x_j, theta>` and `u_j^- = max <-x_j, theta>` over
//! Omega in four geometric cases; feature j is discarded iff both are < 1.
//!
//! Per-feature work is O(1) on top of the shared statistics
//! (`<x_j, theta1>` from the dual state, `<x_j, y>` and `||x_j||^2` from the
//! path precompute), so a full screen is O(p) after the O(n·p) stats pass
//! the path already performs.

use crate::screening::{Geometry, Rule, RuleKind, ScreenContext, ScreenOutcome};
use crate::solver::DualState;
use crate::SCREEN_EPS;

pub struct SasviRule;

/// The two Theorem-3 bounds for one feature, given shared geometry.
///
/// Inputs: `xt1 = <x_j, theta1>`, `xty = <x_j, y>`, `xn2 = ||x_j||^2`.
#[inline]
pub fn feature_bounds(g: &Geometry, xt1: f64, xty: f64, xn2: f64) -> (f64, f64) {
    let xja = xty / g.lam1 - xt1; // <x_j, a>
    let xjb = xja + g.d * xty; // <x_j, b>
    let bnorm = g.bnorm2.sqrt();
    let xnorm = xn2.sqrt();

    // Ball-only closed form (Eq. 28/29): used in case 4 (a = 0) and in the
    // "tail" subcases 2/3 where the optimizer hits only the ball.
    let u_plus_ball = xt1 + 0.5 * (xnorm * bnorm + xjb);
    let u_minus_ball = -xt1 + 0.5 * (xnorm * bnorm - xjb);

    if g.a_is_zero {
        return (u_plus_ball, u_minus_ball);
    }

    // Projections onto the null space of a (Eqs. 21–23 via inner products).
    let xperp2 = (xn2 - xja * xja / g.anorm2).max(0.0);
    let xperp_yperp = xty - g.ay * xja / g.anorm2;
    let cross = (xperp2 * g.yperp2).sqrt();

    // Half-space-active closed form (Eq. 26/27).
    let u_plus_cap = xt1 + 0.5 * g.d * (cross + xperp_yperp);
    let u_minus_cap = -xt1 + 0.5 * g.d * (cross - xperp_yperp);

    // Case split: "<b,a>/||b|| <= s <x_j,a>/||x_j||" with s = ∓1 decides
    // whether the ±x_j maximizer sees the half-space. Multiplied through by
    // the nonnegative norms to avoid division.
    let plus_tail = xja < 0.0 && g.ba * xnorm <= -xja * bnorm;
    let minus_tail = xja > 0.0 && g.ba * xnorm <= xja * bnorm;

    (
        if plus_tail { u_plus_ball } else { u_plus_cap },
        if minus_tail { u_minus_ball } else { u_minus_cap },
    )
}

impl Rule for SasviRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Sasvi
    }

    fn bounds(&self, ctx: &ScreenContext, state: &DualState, lam2: f64, out: &mut [f64]) {
        let g = Geometry::compute(ctx, state, lam2);
        let xt = &state.xt_theta;
        let xty = &ctx.pre.xty;
        let xn2 = &ctx.pre.col_norms_sq;
        crate::linalg::par::fill_columns(out, |j| {
            let (up, um) = feature_bounds(&g, xt[j], xty[j], xn2[j]);
            up.max(um)
        });
    }

    fn screen(
        &self,
        ctx: &ScreenContext,
        state: &DualState,
        lam2: f64,
        keep: &mut [bool],
    ) -> ScreenOutcome {
        let g = Geometry::compute(ctx, state, lam2);
        let xt = &state.xt_theta;
        let xty = &ctx.pre.xty;
        let xn2 = &ctx.pre.col_norms_sq;
        let thr = 1.0 - SCREEN_EPS;
        let kept = crate::linalg::par::fill_mask_count(keep, |j| {
            let (up, um) = feature_bounds(&g, xt[j], xty[j], xn2[j]);
            up >= thr || um >= thr
        });
        ScreenOutcome { kept, screened: ctx.p() - kept }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::solver::cd::{solve_cd, CdOptions};
    use crate::solver::DualState;

    fn solved_state(
        ds: &crate::data::Dataset,
        lam1: f64,
    ) -> (DualState, Vec<f64>) {
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        solve_cd(&ds.x, &ds.y, lam1, &active, &norms, &mut beta, &mut resid,
                 &CdOptions::default());
        (DualState::from_residual(&ds.x, &resid, lam1), beta)
    }

    fn exact_beta(ds: &crate::data::Dataset, lam: f64) -> Vec<f64> {
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        let opts = CdOptions { gap_tol: 1e-12, tol: 1e-12, max_epochs: 20_000, ..Default::default() };
        solve_cd(&ds.x, &ds.y, lam, &active, &norms, &mut beta, &mut resid, &opts);
        beta
    }

    #[test]
    fn safety_screened_features_are_zero() {
        for seed in [1u64, 5, 9, 33] {
            let ds = SyntheticSpec { n: 30, p: 120, nnz: 12, ..Default::default() }
                .generate(seed);
            let pre = ds.precompute();
            let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
            let lam1 = 0.7 * pre.lambda_max;
            let lam2 = 0.5 * pre.lambda_max;
            let (st, _) = solved_state(&ds, lam1);
            let mut keep = vec![false; ds.p()];
            let o = SasviRule.screen(&ctx, &st, lam2, &mut keep);
            assert!(o.screened > 0, "should screen something (seed {seed})");
            let beta2 = exact_beta(&ds, lam2);
            for j in 0..ds.p() {
                if !keep[j] {
                    assert!(
                        beta2[j].abs() < 1e-9,
                        "seed {seed}: screened feature {j} has beta {}",
                        beta2[j]
                    );
                }
            }
        }
    }

    #[test]
    fn safety_from_lambda_max() {
        let ds = SyntheticSpec { n: 25, p: 80, nnz: 8, ..Default::default() }
            .generate(2);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let st = DualState::at_lambda_max(&ds.x, &ds.y, pre.lambda_max, &pre.xty);
        let lam2 = 0.85 * pre.lambda_max;
        let mut keep = vec![false; ds.p()];
        let o = SasviRule.screen(&ctx, &st, lam2, &mut keep);
        assert!(o.screened > 0);
        let beta2 = exact_beta(&ds, lam2);
        for j in 0..ds.p() {
            if !keep[j] {
                assert!(beta2[j].abs() < 1e-9, "feature {j}");
            }
        }
    }

    #[test]
    fn limit_lambda2_to_lambda1() {
        // As lam2 -> lam1 the bounds collapse to +-<x_j, theta1>.
        let ds = SyntheticSpec { n: 20, p: 40, nnz: 5, ..Default::default() }
            .generate(11);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.6 * pre.lambda_max;
        let (st, _) = solved_state(&ds, lam1);
        let g = Geometry::compute(&ctx, &st, lam1 * (1.0 - 1e-9));
        for j in 0..ds.p() {
            let (up, um) = feature_bounds(&g, st.xt_theta[j], pre.xty[j],
                                          pre.col_norms_sq[j]);
            assert!((up - st.xt_theta[j]).abs() < 1e-5, "j={j}");
            assert!((um + st.xt_theta[j]).abs() < 1e-5, "j={j}");
        }
    }

    #[test]
    fn bounds_always_at_least_xt_theta1() {
        // theta1 is in Omega, so u+ >= <x_j,theta1> and u- >= -<x_j,theta1>.
        let ds = SyntheticSpec { n: 25, p: 60, nnz: 6, ..Default::default() }
            .generate(4);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.5 * pre.lambda_max;
        let (st, _) = solved_state(&ds, lam1);
        for f in [0.9, 0.6, 0.3] {
            let g = Geometry::compute(&ctx, &st, f * lam1);
            for j in 0..ds.p() {
                let (up, um) = feature_bounds(&g, st.xt_theta[j], pre.xty[j],
                                              pre.col_norms_sq[j]);
                assert!(up >= st.xt_theta[j] - 1e-9);
                assert!(um >= -st.xt_theta[j] - 1e-9);
            }
        }
    }

    #[test]
    fn rejects_more_than_dpp_and_safe() {
        // §3: Sasvi's feasible set is contained in both relaxations, so its
        // kept set must be a subset of each.
        use crate::screening::{dpp::DppRule, safe::SafeRule};
        let ds = SyntheticSpec { n: 40, p: 200, nnz: 20, ..Default::default() }
            .generate(8);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.8 * pre.lambda_max;
        let (st, _) = solved_state(&ds, lam1);
        for f in [0.95, 0.8, 0.5] {
            let lam2 = f * lam1;
            let mut k_sasvi = vec![false; ds.p()];
            let mut k_dpp = vec![false; ds.p()];
            let mut k_safe = vec![false; ds.p()];
            let o_sasvi = SasviRule.screen(&ctx, &st, lam2, &mut k_sasvi);
            let o_dpp = DppRule.screen(&ctx, &st, lam2, &mut k_dpp);
            let o_safe = SafeRule.screen(&ctx, &st, lam2, &mut k_safe);
            // Per-feature dominance vs DPP is provable (Omega is contained
            // in the DPP ball: add the two VIs + Cauchy-Schwarz). For SAFE
            // the constructions instantiate the VI at different points, so
            // only the aggregate comparison is asserted (it holds with large
            // margin on every dataset in the paper and here).
            for j in 0..ds.p() {
                if k_sasvi[j] {
                    assert!(k_dpp[j], "Sasvi kept {j} but DPP screened it?!");
                }
            }
            let _ = &k_safe;
            assert!(o_sasvi.screened >= o_dpp.screened);
            assert!(o_sasvi.screened >= o_safe.screened);
        }
    }
}
