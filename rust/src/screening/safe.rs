//! SAFE (El Ghaoui, Viallon, Rabbani), sequential form — §3.2 of the paper.
//!
//! The rule bounds `|<x_j, theta_2^*>|` over the ball
//! `||theta - y/lam2|| <= ||s* theta1 - y/lam2||` where
//! `s* = clip(<theta1, y> / (lam2 ||theta1||^2), -1, 1)` is the optimal dual
//! scaling (Eq. 32). The bound (Eq. 33) is
//! `|<x_j, y>|/lam2 + ||x_j|| * ||s* theta1 - y/lam2||`.

use crate::linalg::ops;
use crate::screening::{Rule, RuleKind, ScreenContext};
use crate::solver::DualState;

pub struct SafeRule;

/// Shared per-invocation scalars for the SAFE bound.
pub struct SafeGeometry {
    pub lam2: f64,
    pub radius: f64,
}

impl SafeGeometry {
    pub fn compute(ctx: &ScreenContext, state: &DualState, lam2: f64) -> Self {
        let tnorm2 = ops::nrm2sq(&state.theta);
        let ty = ops::dot(&state.theta, ctx.y);
        let s = if tnorm2 > 0.0 {
            (ty / (lam2 * tnorm2)).clamp(-1.0, 1.0)
        } else {
            0.0
        };
        // ||s theta1 - y/lam2||^2 expanded via precomputed scalars
        let r2 = s * s * tnorm2 - 2.0 * s * ty / lam2
            + ctx.pre.y_norm_sq / (lam2 * lam2);
        SafeGeometry { lam2, radius: r2.max(0.0).sqrt() }
    }
}

impl Rule for SafeRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Safe
    }

    fn bounds(&self, ctx: &ScreenContext, state: &DualState, lam2: f64, out: &mut [f64]) {
        let g = SafeGeometry::compute(ctx, state, lam2);
        let xty = &ctx.pre.xty;
        let xn2 = &ctx.pre.col_norms_sq;
        crate::linalg::par::fill_columns(out, |j| {
            xty[j].abs() / g.lam2 + xn2[j].sqrt() * g.radius
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::solver::cd::{solve_cd, CdOptions};

    fn solved_state(ds: &crate::data::Dataset, lam1: f64) -> DualState {
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        solve_cd(&ds.x, &ds.y, lam1, &active, &norms, &mut beta, &mut resid,
                 &CdOptions::default());
        DualState::from_residual(&ds.x, &resid, lam1)
    }

    #[test]
    fn safety() {
        let ds = SyntheticSpec { n: 30, p: 100, nnz: 10, ..Default::default() }
            .generate(14);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.9 * pre.lambda_max;
        let lam2 = 0.8 * pre.lambda_max;
        let st = solved_state(&ds, lam1);
        let mut keep = vec![false; ds.p()];
        let o = SafeRule.screen(&ctx, &st, lam2, &mut keep);
        // solve exactly at lam2
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta2 = vec![0.0; ds.p()];
        let mut resid2 = ds.y.clone();
        let opts = CdOptions { gap_tol: 1e-12, tol: 1e-12, ..Default::default() };
        solve_cd(&ds.x, &ds.y, lam2, &active, &norms, &mut beta2, &mut resid2, &opts);
        for j in 0..ds.p() {
            if !keep[j] {
                assert!(beta2[j].abs() < 1e-9, "screened {j} has beta {}", beta2[j]);
            }
        }
        // SAFE does screen close to lambda_max
        assert!(o.screened > 0);
    }

    #[test]
    fn bound_contains_true_dual_product() {
        let ds = SyntheticSpec { n: 25, p: 60, nnz: 6, ..Default::default() }
            .generate(3);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.7 * pre.lambda_max;
        let lam2 = 0.55 * pre.lambda_max;
        let st1 = solved_state(&ds, lam1);
        let st2 = solved_state(&ds, lam2);
        let mut bounds = vec![0.0; ds.p()];
        SafeRule.bounds(&ctx, &st1, lam2, &mut bounds);
        for j in 0..ds.p() {
            assert!(
                st2.xt_theta[j].abs() <= bounds[j] + 1e-7,
                "j={j}: |<x_j,theta2>|={} > bound {}",
                st2.xt_theta[j].abs(),
                bounds[j]
            );
        }
    }
}
