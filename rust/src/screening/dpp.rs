//! DPP (Wang, Lin, Gong, Wonka, Ye), sequential form — §3.3 of the paper.
//!
//! The rule bounds `|<x_j, theta_2^*>|` over the ball centered at `theta1`
//! with radius `||y/lam2 - y/lam1|| = ||y|| (1/lam2 - 1/lam1)` (Eq. 38),
//! obtained from adding the two variational inequalities and relaxing via
//! Cauchy–Schwarz. The bound is
//! `|<x_j, theta1>| + ||x_j|| * ||y|| (1/lam2 - 1/lam1)`.

use crate::screening::{Rule, RuleKind, ScreenContext};
use crate::solver::DualState;

pub struct DppRule;

impl Rule for DppRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Dpp
    }

    fn bounds(&self, ctx: &ScreenContext, state: &DualState, lam2: f64, out: &mut [f64]) {
        let radius = ctx.pre.y_norm_sq.sqrt() * (1.0 / lam2 - 1.0 / state.lambda);
        let xt = &state.xt_theta;
        let xn2 = &ctx.pre.col_norms_sq;
        crate::linalg::par::fill_columns(out, |j| xt[j].abs() + xn2[j].sqrt() * radius);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::solver::cd::{solve_cd, CdOptions};

    fn solved_state(ds: &crate::data::Dataset, lam1: f64) -> DualState {
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        solve_cd(&ds.x, &ds.y, lam1, &active, &norms, &mut beta, &mut resid,
                 &CdOptions::default());
        DualState::from_residual(&ds.x, &resid, lam1)
    }

    #[test]
    fn safety() {
        let ds = SyntheticSpec { n: 30, p: 100, nnz: 10, ..Default::default() }
            .generate(23);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.9 * pre.lambda_max;
        let lam2 = 0.8 * pre.lambda_max;
        let st = solved_state(&ds, lam1);
        let mut keep = vec![false; ds.p()];
        let o = DppRule.screen(&ctx, &st, lam2, &mut keep);
        assert!(o.screened > 0);
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta2 = vec![0.0; ds.p()];
        let mut resid2 = ds.y.clone();
        let opts = CdOptions { gap_tol: 1e-12, tol: 1e-12, ..Default::default() };
        solve_cd(&ds.x, &ds.y, lam2, &active, &norms, &mut beta2, &mut resid2, &opts);
        for j in 0..ds.p() {
            if !keep[j] {
                assert!(beta2[j].abs() < 1e-9, "screened {j} has beta {}", beta2[j]);
            }
        }
    }

    #[test]
    fn ball_actually_contains_theta2() {
        // ||theta2 - theta1|| <= ||y||(1/lam2 - 1/lam1) (Eq. 38)
        let ds = SyntheticSpec { n: 20, p: 50, nnz: 5, ..Default::default() }
            .generate(6);
        let pre = ds.precompute();
        let lam1 = 0.6 * pre.lambda_max;
        let lam2 = 0.4 * pre.lambda_max;
        let st1 = solved_state(&ds, lam1);
        let st2 = solved_state(&ds, lam2);
        let mut diff = 0.0;
        for (a, b) in st2.theta.iter().zip(st1.theta.iter()) {
            diff += (a - b) * (a - b);
        }
        let radius = pre.y_norm_sq.sqrt() * (1.0 / lam2 - 1.0 / lam1);
        assert!(diff.sqrt() <= radius + 1e-7, "{} vs {}", diff.sqrt(), radius);
    }

    #[test]
    fn bound_shrinks_as_lam2_approaches_lam1() {
        let ds = SyntheticSpec { n: 20, p: 30, nnz: 3, ..Default::default() }
            .generate(9);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.5 * pre.lambda_max;
        let st = solved_state(&ds, lam1);
        let mut near = vec![0.0; ds.p()];
        let mut far = vec![0.0; ds.p()];
        DppRule.bounds(&ctx, &st, 0.95 * lam1, &mut near);
        DppRule.bounds(&ctx, &st, 0.5 * lam1, &mut far);
        for j in 0..ds.p() {
            assert!(near[j] <= far[j] + 1e-12);
        }
    }
}
