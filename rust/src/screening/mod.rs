//! Screening rules for the pathwise Lasso.
//!
//! Each rule answers, for every feature `j`, whether `beta_j = 0` is
//! *guaranteed* at the next grid point `lambda_2` given the solved state at
//! `lambda_1` (dual point `theta_1^*`). The test is Eq. (4) of the paper:
//! `|<x_j, theta_2^*>| < 1 => beta_j^* = 0`, with each rule bounding the
//! unknown `<x_j, theta_2^*>` over its own feasible set:
//!
//! * [`sasvi`] — the paper's contribution: half-space ∩ ball from the two
//!   variational inequalities (Theorem 3);
//! * [`safe`] — El Ghaoui et al.'s ball (a relaxation of one VI, §3.2);
//! * [`dpp`] — Wang et al.'s ball (a relaxation of both VIs, §3.3);
//! * [`strong`] — Tibshirani et al.'s heuristic (unsafe; needs KKT
//!   correction, which the coordinator performs);
//! * [`RuleKind::None`] — no screening (the plain-solver baseline).
//!
//! Per-feature rule evaluation is batched over column blocks on the
//! [`crate::linalg::par`] pool (shared per-invocation geometry is computed
//! once, then each block evaluates its features with the same serial
//! arithmetic), so screening results are bit-identical at every thread
//! count.
//!
//! ## Dynamic screening ([`dynamic`])
//!
//! The rules above screen once per grid point. [`dynamic`] re-applies a
//! fused VI-ball + gap-ball test *inside* the solvers, every
//! `recheck_every` epochs, with a dual-feasible point scaled from the
//! current residual. **The dynamic contract:** a re-screen is safe
//! whenever the surviving set it starts from is itself safe — the test
//! certifies zeros of the problem restricted to the survivors, and safe
//! restrictions compose. Along a path that means: safe rule screens →
//! every dynamic discard is exact; strong rule screens → dynamic discards
//! inherit the rule's "restricted-safe" status and are repaired by the
//! same KKT correction. `rust/tests/dynamic_safety.rs` pins the guarantee
//! per checkpoint; `rust/tests/determinism.rs` pins bit-identity across
//! thread counts and objective agreement with the static path.
//!
//! Screening's complement — *growing* a working set by KKT violators,
//! using the same fused test as the prune half of one shared checkpoint —
//! lives in [`crate::solver::working_set`].

pub mod dpp;
pub mod dynamic;
pub mod safe;
pub mod sasvi;
pub mod strong;
pub mod sure_removal;

use crate::data::dataset::PathPrecompute;
use crate::linalg::DesignMatrix;
use crate::solver::DualState;
use crate::SCREEN_EPS;

/// Everything a rule may read that is constant along the whole path.
/// The design matrix is behind the [`DesignMatrix`] abstraction, so rules
/// work identically over dense and CSC storage (they mostly consume the
/// precomputed per-feature statistics anyway).
pub struct ScreenContext<'a> {
    pub x: &'a DesignMatrix,
    pub y: &'a [f64],
    pub pre: &'a PathPrecompute,
}

impl<'a> ScreenContext<'a> {
    pub fn new(x: &'a DesignMatrix, y: &'a [f64], pre: &'a PathPrecompute) -> Self {
        Self { x, y, pre }
    }

    pub fn p(&self) -> usize {
        self.x.ncols()
    }
}

/// Outcome counts of one screening invocation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScreenOutcome {
    pub kept: usize,
    pub screened: usize,
}

impl ScreenOutcome {
    pub fn from_mask(keep: &[bool]) -> Self {
        let kept = keep.iter().filter(|&&k| k).count();
        Self { kept, screened: keep.len() - kept }
    }

    /// The paper's Fig. 5 quantity.
    pub fn rejection_ratio(&self) -> f64 {
        let total = self.kept + self.screened;
        if total == 0 {
            0.0
        } else {
            self.screened as f64 / total as f64
        }
    }
}

/// A screening rule. Implementations must be pure functions of their inputs
/// (the coordinator calls them from worker threads).
pub trait Rule: Send + Sync {
    fn kind(&self) -> RuleKind;

    /// Safe rules guarantee screened features are zero in the true solution;
    /// unsafe rules (strong) require post-hoc KKT correction.
    fn is_safe(&self) -> bool {
        true
    }

    /// Write the per-feature upper bounds on `|<x_j, theta_2^*>|` into
    /// `out`. For rules with asymmetric bounds (Sasvi) this is
    /// `max(u_j^+, u_j^-)`.
    fn bounds(&self, ctx: &ScreenContext, state: &DualState, lam2: f64, out: &mut [f64]);

    /// Fill `keep[j] = bound_j >= 1 - SCREEN_EPS`. The default implements
    /// this via [`Rule::bounds`]; rules may override with a fused loop.
    /// Both the bounds pass and the mask fill run on the
    /// [`crate::linalg::par`] column-block pool.
    fn screen(
        &self,
        ctx: &ScreenContext,
        state: &DualState,
        lam2: f64,
        keep: &mut [bool],
    ) -> ScreenOutcome {
        let mut bounds = vec![0.0; ctx.p()];
        self.bounds(ctx, state, lam2, &mut bounds);
        let thr = 1.0 - SCREEN_EPS;
        let kept = crate::linalg::par::fill_mask_count(keep, |j| bounds[j] >= thr);
        ScreenOutcome { kept, screened: keep.len() - kept }
    }
}

/// Enumeration of the available rules (CLI / config / bench selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RuleKind {
    /// no screening: keep everything
    None,
    Safe,
    Dpp,
    Strong,
    Sasvi,
}

impl RuleKind {
    pub fn parse(s: &str) -> Option<RuleKind> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "solver" => Some(RuleKind::None),
            "safe" => Some(RuleKind::Safe),
            "dpp" => Some(RuleKind::Dpp),
            "strong" => Some(RuleKind::Strong),
            "sasvi" => Some(RuleKind::Sasvi),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::None => "solver",
            RuleKind::Safe => "SAFE",
            RuleKind::Dpp => "DPP",
            RuleKind::Strong => "Strong",
            RuleKind::Sasvi => "Sasvi",
        }
    }

    pub fn build(&self) -> Box<dyn Rule> {
        match self {
            RuleKind::None => Box::new(NoRule),
            RuleKind::Safe => Box::new(safe::SafeRule),
            RuleKind::Dpp => Box::new(dpp::DppRule),
            RuleKind::Strong => Box::new(strong::StrongRule),
            RuleKind::Sasvi => Box::new(sasvi::SasviRule),
        }
    }

    pub fn all() -> [RuleKind; 5] {
        [RuleKind::None, RuleKind::Safe, RuleKind::Dpp, RuleKind::Strong, RuleKind::Sasvi]
    }
}

/// The no-op rule: keeps every feature (baseline "solver" row of Table 1).
pub struct NoRule;

impl Rule for NoRule {
    fn kind(&self) -> RuleKind {
        RuleKind::None
    }

    fn bounds(&self, _ctx: &ScreenContext, _state: &DualState, _lam2: f64, out: &mut [f64]) {
        out.fill(f64::INFINITY);
    }

    fn screen(
        &self,
        _ctx: &ScreenContext,
        _state: &DualState,
        _lam2: f64,
        keep: &mut [bool],
    ) -> ScreenOutcome {
        keep.fill(true);
        ScreenOutcome { kept: keep.len(), screened: 0 }
    }
}

/// Shared per-invocation geometry: the quantities every VI-based rule needs,
/// derived once per (state, lam2) pair in O(n).
///
///   a = y/lam1 - theta1         (scaled prediction, Eq. 17)
///   b = y/lam2 - theta1 = a + d*y,   d = 1/lam2 - 1/lam1
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    pub lam1: f64,
    pub lam2: f64,
    pub d: f64,
    pub anorm2: f64,
    pub ay: f64,
    pub ynorm2: f64,
    pub bnorm2: f64,
    pub ba: f64,
    /// ||y_perp||^2 = ||y||^2 - <a,y>^2/||a||^2 (0 when a = 0)
    pub yperp2: f64,
    pub a_is_zero: bool,
}

impl Geometry {
    pub fn compute(ctx: &ScreenContext, state: &DualState, lam2: f64) -> Self {
        use crate::linalg::ops;
        let lam1 = state.lambda;
        let ynorm2 = ctx.pre.y_norm_sq;
        let ty = ops::dot(&state.theta, ctx.y);
        let tnorm2 = ops::nrm2sq(&state.theta);
        // a = y/lam1 - theta1
        let anorm2 = (ynorm2 / (lam1 * lam1) - 2.0 * ty / lam1 + tnorm2).max(0.0);
        let ay = ynorm2 / lam1 - ty;
        Self::from_scalars(lam1, lam2, anorm2, ay, ynorm2)
    }

    /// Build from the three `a`/`y` scalars — O(1); used by the
    /// sure-removal scans that evaluate many `lam2` values per state.
    pub fn from_scalars(lam1: f64, lam2: f64, anorm2: f64, ay: f64, ynorm2: f64) -> Self {
        let d = 1.0 / lam2 - 1.0 / lam1;
        let bnorm2 = (anorm2 + 2.0 * d * ay + d * d * ynorm2).max(0.0);
        let ba = anorm2 + d * ay;
        let a_is_zero = anorm2 <= 1e-20 * ynorm2.max(1.0);
        let yperp2 = if a_is_zero {
            0.0
        } else {
            (ynorm2 - ay * ay / anorm2).max(0.0)
        };
        Geometry {
            lam1,
            lam2,
            d,
            anorm2,
            ay,
            ynorm2,
            bnorm2,
            ba,
            yperp2,
            a_is_zero,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    #[test]
    fn rulekind_parse_and_names() {
        for k in RuleKind::all() {
            let name = k.name().to_ascii_lowercase();
            assert_eq!(RuleKind::parse(&name), Some(k));
        }
        assert_eq!(RuleKind::parse("bogus"), None);
    }

    #[test]
    fn outcome_counts() {
        let keep = [true, false, false, true];
        let o = ScreenOutcome::from_mask(&keep);
        assert_eq!(o, ScreenOutcome { kept: 2, screened: 2 });
        assert!((o.rejection_ratio() - 0.5).abs() < 1e-15);
    }

    #[test]
    fn geometry_at_lambda_max_has_zero_a() {
        let ds = SyntheticSpec { n: 20, p: 40, nnz: 4, ..Default::default() }
            .generate(3);
        let pre = ds.precompute();
        let st = DualState::at_lambda_max(&ds.x, &ds.y, pre.lambda_max, &pre.xty);
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let g = Geometry::compute(&ctx, &st, 0.8 * pre.lambda_max);
        assert!(g.a_is_zero, "anorm2={}", g.anorm2);
        // b = d*y
        assert!((g.bnorm2 - g.d * g.d * g.ynorm2).abs() < 1e-9 * g.ynorm2);
    }

    #[test]
    fn geometry_ba_nonnegative_theorem1() {
        // Theorem 1: <b, a> >= 0 for any valid dual state
        let ds = SyntheticSpec { n: 25, p: 50, nnz: 5, ..Default::default() }
            .generate(7);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        // solve at lam1 to get a real dual point
        let lam1 = 0.6 * pre.lambda_max;
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        crate::solver::cd::solve_cd(
            &ds.x, &ds.y, lam1, &active, &norms, &mut beta, &mut resid,
            &crate::solver::cd::CdOptions::default(),
        );
        let st = DualState::from_residual(&ds.x, &resid, lam1);
        for f in [0.9, 0.5, 0.2] {
            let g = Geometry::compute(&ctx, &st, f * lam1);
            assert!(g.ba >= -1e-9, "ba = {}", g.ba);
            assert!(g.bnorm2 > 0.0);
        }
    }

    #[test]
    fn no_rule_keeps_everything() {
        let ds = SyntheticSpec { n: 10, p: 20, nnz: 2, ..Default::default() }
            .generate(1);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let st = DualState::at_lambda_max(&ds.x, &ds.y, pre.lambda_max, &pre.xty);
        let mut keep = vec![false; ds.p()];
        let o = NoRule.screen(&ctx, &st, 0.5 * pre.lambda_max, &mut keep);
        assert_eq!(o.kept, ds.p());
        assert!(keep.iter().all(|&k| k));
    }
}
