//! The (sequential) strong rule — Tibshirani et al., Eq. (31) of the paper.
//!
//! Heuristic: assumes the unit-slope bound
//! `|lam2 <x_j, theta2> - lam1 <x_j, theta1>| <= lam1 - lam2`, giving
//! `|<x_j, theta2>| <= (lam1/lam2) |<x_j, theta1>| + (lam1/lam2 - 1)`.
//! The assumption can fail, so discarded features must be re-checked
//! against the KKT conditions after the solve; the coordinator performs
//! that correction loop (`is_safe() == false` signals it).

use crate::screening::{Rule, RuleKind, ScreenContext};
use crate::solver::DualState;

pub struct StrongRule;

impl Rule for StrongRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Strong
    }

    fn is_safe(&self) -> bool {
        false
    }

    fn bounds(&self, _ctx: &ScreenContext, state: &DualState, lam2: f64, out: &mut [f64]) {
        let ratio = state.lambda / lam2;
        let slack = ratio - 1.0;
        let xt = &state.xt_theta;
        crate::linalg::par::fill_columns(out, |j| ratio * xt[j].abs() + slack);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::solver::cd::{solve_cd, CdOptions};

    #[test]
    fn screens_aggressively() {
        // The strong rule should discard at least as many features as DPP
        // on a typical instance (it is *much* tighter, at the cost of
        // safety).
        use crate::screening::dpp::DppRule;
        let ds = SyntheticSpec { n: 30, p: 150, nnz: 15, ..Default::default() }
            .generate(31);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.7 * pre.lambda_max;
        let active: Vec<usize> = (0..ds.p()).collect();
        let norms = ds.x.col_norms_sq();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        solve_cd(&ds.x, &ds.y, lam1, &active, &norms, &mut beta, &mut resid,
                 &CdOptions::default());
        let st = DualState::from_residual(&ds.x, &resid, lam1);
        let lam2 = 0.5 * pre.lambda_max;
        let mut k_strong = vec![false; ds.p()];
        let mut k_dpp = vec![false; ds.p()];
        let o_strong = StrongRule.screen(&ctx, &st, lam2, &mut k_strong);
        let o_dpp = DppRule.screen(&ctx, &st, lam2, &mut k_dpp);
        assert!(o_strong.screened >= o_dpp.screened);
    }

    #[test]
    fn is_flagged_unsafe() {
        assert!(!StrongRule.is_safe());
        assert!(crate::screening::sasvi::SasviRule.is_safe());
    }

    #[test]
    fn bound_formula_spotcheck() {
        // hand-check Eq. 31 at a point: ratio * |xt| + ratio - 1
        let ds = SyntheticSpec { n: 10, p: 5, nnz: 1, ..Default::default() }
            .generate(1);
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let st = DualState::at_lambda_max(&ds.x, &ds.y, pre.lambda_max, &pre.xty);
        let lam2 = 0.5 * pre.lambda_max;
        let mut bounds = vec![0.0; 5];
        StrongRule.bounds(&ctx, &st, lam2, &mut bounds);
        for j in 0..5 {
            let want = 2.0 * st.xt_theta[j].abs() + 1.0;
            assert!((bounds[j] - want).abs() < 1e-12);
        }
    }
}
