//! ISSUE 10 acceptance: the penalty-generic path contract.
//!
//! `prop_penalty_path_matches_unscreened` sweeps every penalty (ℓ1,
//! elastic net, sparse-group lasso) over both storage backends (dense and
//! 5% CSC), both solvers (CD, FISTA) and every in-solver mode (plain,
//! dynamic re-screening, working-set driving), and checks the standing
//! contracts extend unchanged:
//!
//!   * screened-path objectives match the unscreened path to 1e-8 at
//!     every grid point (computed with the penalty-generic
//!     [`sasvi::solver::primal_objective_pen`]),
//!   * screened and unscreened coefficients agree (so screening never
//!     zeroed a genuinely active feature),
//!   * screening is non-vacuous (something was actually discarded),
//!   * the screened path is bit-identical across thread counts.
//!
//! The second test is the elastic-net parity satellite: the native
//! `Penalty::ElasticNet` path must match the pre-existing
//! [`sasvi::data::elastic_net::augment`] reduction (Lasso on
//! `[X; sqrt(alpha) I]`) — objectives to 1e-8 and coefficients
//! elementwise — on dense and sparse data.

use std::sync::Mutex;

use sasvi::coordinator::{run_path_keep_betas, PathOptions, PathPlan, SolverKind};
use sasvi::data::synthetic::SyntheticSpec;
use sasvi::data::Dataset;
use sasvi::linalg::par;
use sasvi::penalty::{GroupSpec, Penalty};
use sasvi::screening::dynamic::DynamicOptions;
use sasvi::screening::RuleKind;
use sasvi::solver::cd::CdOptions;
use sasvi::solver::primal_objective_pen;
use sasvi::solver::working_set::WorkingSetOptions;

/// Path-running tests retune the process-wide thread knob; serialize them.
static THREAD_KNOB: Mutex<()> = Mutex::new(());

/// A sparse synthetic problem at 5% density plus its densified twin.
fn backend_pair(seed: u64) -> (Dataset, Dataset) {
    let sp = SyntheticSpec {
        n: 60,
        p: 200,
        nnz: 15,
        density: 0.05,
        ..Default::default()
    }
    .generate(seed);
    assert!(sp.x.is_sparse());
    let mut dn = sp.clone();
    dn.x = sp.x.to_dense().into();
    (dn, sp)
}

/// Penalty-generic primal objective of a solution against a dataset.
fn objective(ds: &Dataset, beta: &[f64], lam: f64, pen: &Penalty) -> f64 {
    let mut fit = vec![0.0; ds.n()];
    ds.x.matvec(beta, &mut fit);
    let resid: Vec<f64> = ds.y.iter().zip(fit.iter()).map(|(y, f)| y - f).collect();
    primal_objective_pen(pen, &resid, beta, lam)
}

fn penalties() -> [Penalty; 3] {
    [
        Penalty::L1,
        Penalty::ElasticNet { alpha: 0.3 },
        Penalty::SparseGroupLasso { groups: GroupSpec::new(8), tau: 0.5 },
    ]
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: index {k}: {x} vs {y}");
    }
}

#[test]
fn prop_penalty_path_matches_unscreened() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    // tight solves so every comparison lands well inside the 1e-8 bar
    let cd = CdOptions {
        max_epochs: 30_000,
        tol: 1e-12,
        gap_tol: 1e-12,
        ..Default::default()
    };
    let fista = sasvi::solver::FistaOptions { max_iters: 20_000, tol: 1e-13, lipschitz: None };
    let (dn, sp) = backend_pair(17);
    for pen in penalties() {
        for ds in [&dn, &sp] {
            let plan = PathPlan::linear_spaced(ds, 8, 0.15);
            for solver in [SolverKind::Cd, SolverKind::Fista] {
                // unscreened reference: no rule, no in-solver machinery
                par::set_threads(1);
                let base_opts = PathOptions { solver, cd, fista, penalty: pen, ..Default::default() };
                let baseline = run_path_keep_betas(ds, &plan, RuleKind::None, base_opts);
                let base_betas = baseline.betas.as_ref().unwrap();
                for (mode, dynamic, working_set) in [
                    ("plain", DynamicOptions::off(), WorkingSetOptions::off()),
                    ("dynamic", DynamicOptions::enabled_every(3), WorkingSetOptions::off()),
                    ("ws", DynamicOptions::off(), WorkingSetOptions::enabled_with_grow(7)),
                ] {
                    let opts = PathOptions {
                        solver,
                        cd,
                        fista,
                        dynamic,
                        working_set,
                        penalty: pen,
                        ..Default::default()
                    };
                    let tag = format!(
                        "{} {solver:?} {mode} {}",
                        pen.spec(),
                        ds.x.storage()
                    );
                    par::set_threads(1);
                    let screened = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts);
                    let scr_betas = screened.betas.as_ref().unwrap();
                    let rule_screened: usize =
                        screened.steps.iter().map(|s| s.screened).sum();
                    assert!(rule_screened > 0, "{tag}: screened nothing — vacuous");
                    for (k, lam) in plan.lambdas.iter().enumerate() {
                        let os = objective(ds, &scr_betas[k], *lam, &pen);
                        let ob = objective(ds, &base_betas[k], *lam, &pen);
                        assert!(
                            (os - ob).abs() <= 1e-8 * (1.0 + ob.abs()),
                            "{tag}: step {k} objective {os} vs unscreened {ob}"
                        );
                        for j in 0..ds.p() {
                            // agreement implies zero-safety: a screened-out
                            // (exactly zero) coefficient must be zero in the
                            // unscreened optimum too
                            assert!(
                                (scr_betas[k][j] - base_betas[k][j]).abs() < 1e-6,
                                "{tag}: step {k} feature {j}: {} vs {}",
                                scr_betas[k][j],
                                base_betas[k][j]
                            );
                        }
                    }
                    // the screened path is bit-identical across thread counts
                    for lanes in [4usize] {
                        par::set_threads(lanes);
                        let parallel = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts);
                        let pb = parallel.betas.as_ref().unwrap();
                        for (k, (sa, sb)) in scr_betas.iter().zip(pb.iter()).enumerate() {
                            assert_bits_eq(sa, sb, &format!("{tag}: step {k} lanes {lanes}"));
                        }
                        for (s1, s2) in screened.steps.iter().zip(parallel.steps.iter()) {
                            assert_eq!(s1.kept, s2.kept, "{tag}: kept diverged");
                            assert_eq!(s1.epochs, s2.epochs, "{tag}: epochs diverged");
                        }
                    }
                }
            }
        }
    }
    par::set_threads(before);
}

/// The EN parity satellite: the native elastic-net path equals the
/// augmented-Lasso reduction on the same λ-grid. The augmented problem's
/// Lasso objective equals the original problem's EN objective at the same
/// coefficients, so objectives compare directly through the EN penalty.
#[test]
fn elastic_net_native_path_matches_augmentation() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    par::set_threads(before.max(1));
    let alpha = 0.35;
    let pen = Penalty::ElasticNet { alpha };
    let cd = CdOptions {
        max_epochs: 30_000,
        tol: 1e-12,
        gap_tol: 1e-12,
        ..Default::default()
    };
    let (dn, sp) = backend_pair(29);
    for ds in [&dn, &sp] {
        let aug = sasvi::data::elastic_net::augment(ds, alpha);
        // same grid for both runs: EN and its augmentation share lambda_max
        let plan = PathPlan::linear_spaced(ds, 10, 0.1);
        let native_opts = PathOptions { cd, penalty: pen, ..Default::default() };
        let native = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, native_opts);
        let aug_opts = PathOptions { cd, ..Default::default() };
        let reduced = run_path_keep_betas(&aug, &plan, RuleKind::Sasvi, aug_opts);
        let a = native.betas.as_ref().unwrap();
        let b = reduced.betas.as_ref().unwrap();
        for (k, lam) in plan.lambdas.iter().enumerate() {
            let on = objective(ds, &a[k], *lam, &pen);
            let or = objective(ds, &b[k], *lam, &pen);
            assert!(
                (on - or).abs() <= 1e-8 * (1.0 + or.abs()),
                "({}) step {k}: native EN objective {on} vs augmented {or}",
                ds.x.storage()
            );
            for j in 0..ds.p() {
                assert!(
                    (a[k][j] - b[k][j]).abs() < 1e-6,
                    "({}) step {k} feature {j}: native {} vs augmented {}",
                    ds.x.storage(),
                    a[k][j],
                    b[k][j]
                );
            }
        }
        // both pipelines screened for real
        let native_screened: usize = native.steps.iter().map(|s| s.screened).sum();
        assert!(native_screened > 0, "native EN screening vacuous");
    }
    par::set_threads(before);
}
