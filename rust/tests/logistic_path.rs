//! The §6 logistic workload's safety/exactness battery, in the style of
//! `tests/screening_safety.rs`.
//!
//! * **Gap-safe dynamic safety** (the provable guarantee): every feature a
//!   [`sasvi::logistic::logistic_rescreen`] checkpoint discards mid-solve
//!   must be zero (|beta| < 1e-10) in a high-precision *unscreened*
//!   solution at the same lambda — checked at every checkpoint of every
//!   grid point, on dense and 5%-dense CSC designs.
//! * **Exactness** (the KKT-correction guarantee): the SasviQ- and
//!   Strong-screened logistic paths, with and without the dynamic
//!   checkpoint, agree with the unscreened path to 1e-8 in objective at
//!   every grid point, on both storage backends.

use sasvi::coordinator::logistic::{run_logistic_path_keep_betas, LogisticPathOptions};
use sasvi::coordinator::PathPlan;
use sasvi::data::synthetic::SyntheticSpec;
use sasvi::logistic::{LogiRule, LogisticOptions, LogisticProblem};
use sasvi::screening::dynamic::DynamicOptions;

/// A dense/5%-CSC pair of genuine ±1-label classification problems.
fn backend_pair(seed: u64) -> (LogisticProblem, LogisticProblem) {
    let sp_ds = SyntheticSpec {
        n: 40,
        p: 150,
        nnz: 15,
        density: 0.05,
        classification: true,
        ..Default::default()
    }
    .generate(seed);
    assert!(sp_ds.x.is_sparse());
    let mut dn_ds = sp_ds.clone();
    dn_ds.x = sp_ds.x.to_dense().into();
    let sp = LogisticProblem::from_labels(&sp_ds).expect("generated labels");
    let dn = LogisticProblem::from_labels(&dn_ds).expect("generated labels");
    (dn, sp)
}

fn tight() -> LogisticPathOptions {
    LogisticPathOptions {
        solver: LogisticOptions { tol: 1e-12, max_iters: 30_000, ..Default::default() },
        ..Default::default()
    }
}

fn storage(prob: &LogisticProblem) -> &'static str {
    prob.x.storage()
}

#[test]
fn gap_safe_dynamic_drops_are_safe_at_every_checkpoint() {
    for seed in [3u64, 12] {
        let (dn, sp) = backend_pair(seed);
        for prob in [&dn, &sp] {
            let plan = PathPlan::linear_from_lambda_max(prob.lambda_max(), 7, 0.15);
            // rule None: the kept set entering every solve is the full
            // (trivially safe) set, so each checkpoint's discards must be
            // exact for the full problem — the provable contract
            let opts = LogisticPathOptions {
                dynamic: DynamicOptions::enabled_every(3),
                ..tight()
            };
            let dynamic =
                run_logistic_path_keep_betas(prob, &plan, LogiRule::None, opts);
            let reference =
                run_logistic_path_keep_betas(prob, &plan, LogiRule::None, tight());
            let traces = dynamic.dynamic.as_ref().expect("traces retained");
            assert!(
                dynamic.total_dynamic_dropped() > 0,
                "seed {seed} ({}): no checkpoint ever dropped — vacuous",
                storage(prob)
            );
            let refs = reference.betas.as_ref().unwrap();
            for (k, trace) in traces.iter().enumerate() {
                for ev in &trace.events {
                    for &j in &ev.dropped {
                        assert!(
                            refs[k][j].abs() < 1e-10,
                            "seed {seed} ({}): step {k} checkpoint at iter {} \
                             dropped feature {j} but the unscreened solution \
                             has beta_j = {:e}",
                            storage(prob),
                            ev.epoch,
                            refs[k][j]
                        );
                    }
                    assert!(ev.gap.is_finite(), "non-finite checkpoint gap");
                }
            }
        }
    }
}

#[test]
fn corrected_rule_paths_match_unscreened_objectives() {
    for seed in [5u64, 9] {
        let (dn, sp) = backend_pair(seed);
        for prob in [&dn, &sp] {
            let plan = PathPlan::linear_from_lambda_max(prob.lambda_max(), 8, 0.15);
            let base =
                run_logistic_path_keep_betas(prob, &plan, LogiRule::None, tight());
            let b0 = base.betas.as_ref().unwrap();
            for rule in [LogiRule::Strong, LogiRule::SasviQ] {
                for dynamic in [DynamicOptions::off(), DynamicOptions::enabled_every(4)] {
                    let opts = LogisticPathOptions { dynamic, ..tight() };
                    let r = run_logistic_path_keep_betas(prob, &plan, rule, opts);
                    let screened: usize = r.steps.iter().map(|s| s.screened).sum();
                    assert!(
                        screened > 0,
                        "{rule:?} ({}) screened nothing — vacuous",
                        storage(prob)
                    );
                    let b1 = r.betas.as_ref().unwrap();
                    for (k, lam) in plan.lambdas.iter().enumerate() {
                        let oa = prob.objective(&b0[k], *lam);
                        let ob = prob.objective(&b1[k], *lam);
                        assert!(
                            (oa - ob).abs() <= 1e-8 * (1.0 + oa.abs()),
                            "{rule:?} ({}) dynamic={} step {k}: objective \
                             {oa} vs unscreened {ob}",
                            storage(prob),
                            dynamic.active()
                        );
                    }
                    // solutions live inside the screened-kept set plus the
                    // KKT re-admissions
                    for (s, b) in r.steps.iter().zip(b1.iter()) {
                        let nnz = b.iter().filter(|&&v| v != 0.0).count();
                        assert!(nnz <= s.kept + s.kkt_violations);
                        assert_eq!(nnz, s.nnz);
                    }
                }
            }
        }
    }
}

#[test]
fn dense_and_sparse_backends_agree() {
    let (dn, sp) = backend_pair(21);
    let plan = PathPlan::linear_from_lambda_max(dn.lambda_max(), 6, 0.2);
    let a = run_logistic_path_keep_betas(&dn, &plan, LogiRule::SasviQ, tight());
    let b = run_logistic_path_keep_betas(&sp, &plan, LogiRule::SasviQ, tight());
    for (s1, s2) in a.steps.iter().zip(b.steps.iter()) {
        assert_eq!(s1.kept, s2.kept, "kept-set size diverged across backends");
    }
    let ba = a.betas.as_ref().unwrap();
    let bb = b.betas.as_ref().unwrap();
    for (k, (x, y)) in ba.iter().zip(bb.iter()).enumerate() {
        for j in 0..dn.p() {
            assert!(
                (x[j] - y[j]).abs() < 1e-6,
                "step {k} feature {j}: dense {} vs csc {}",
                x[j],
                y[j]
            );
        }
    }
}

#[test]
fn lambda_max_grid_point_fits_nothing() {
    let (dn, _) = backend_pair(7);
    let plan = PathPlan::linear_from_lambda_max(dn.lambda_max(), 5, 0.3);
    let r = run_logistic_path_keep_betas(&dn, &plan, LogiRule::SasviQ, tight());
    assert_eq!(r.steps[0].nnz, 0, "beta = 0 is optimal at lambda_max");
    // and the dynamic epoch-0 checkpoint discards (nearly) everything there
    let opts = LogisticPathOptions {
        dynamic: DynamicOptions::enabled_every(5),
        ..tight()
    };
    let rd = run_logistic_path_keep_betas(&dn, &plan, LogiRule::SasviQ, opts);
    assert!(
        rd.steps[0].dyn_dropped >= dn.p() - 4,
        "expected a near-total epoch-0 discard at lambda_max, got {}",
        rd.steps[0].dyn_dropped
    );
}
