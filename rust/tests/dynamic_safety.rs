//! Dynamic screening's safety guarantee, pinned per checkpoint.
//!
//! For every λ-path step, every in-solver re-screen checkpoint records the
//! features it discarded. Safety means each of those features is
//! numerically zero (|β_j| < 1e-10) in a high-precision *unscreened* solve
//! at that step's λ — i.e. a dynamic discard is never wrong, no matter how
//! far from converged the solver was when it fired.
//!
//! Runs on both storage backends (dense and 5% CSC), both solvers (CD and
//! compacted FISTA), and both λ-path presets (linear and log grids), with
//! the Sasvi pathwise rule in front and with no pathwise rule at all
//! (pure dynamic screening).

use sasvi::coordinator::{run_path_keep_betas, PathOptions, PathPlan, SolverKind};
use sasvi::data::synthetic::SyntheticSpec;
use sasvi::data::Dataset;
use sasvi::screening::dynamic::DynamicOptions;
use sasvi::screening::RuleKind;
use sasvi::solver::cd::{solve_cd, solve_cd_dynamic, CdOptions};

fn tight() -> CdOptions {
    CdOptions {
        max_epochs: 30_000,
        tol: 1e-13,
        gap_tol: 1e-13,
        ..Default::default()
    }
}

/// High-precision unscreened reference solve.
fn solve_exact(ds: &Dataset, lam: f64) -> Vec<f64> {
    let active: Vec<usize> = (0..ds.p()).collect();
    let norms = ds.x.col_norms_sq();
    let mut beta = vec![0.0; ds.p()];
    let mut resid = ds.y.clone();
    solve_cd(&ds.x, &ds.y, lam, &active, &norms, &mut beta, &mut resid, &tight());
    beta
}

/// A 5%-dense CSC dataset and its densified twin.
fn backend_pair(seed: u64) -> (Dataset, Dataset) {
    let sp = SyntheticSpec {
        n: 100,
        p: 400,
        nnz: 20,
        density: 0.05,
        ..Default::default()
    }
    .generate(seed);
    assert!(sp.x.is_sparse());
    let mut dn = sp.clone();
    dn.x = sp.x.to_dense().into();
    (dn, sp)
}

/// The property: every feature dropped at ANY checkpoint of ANY step is
/// zero in the exact solution at that step's λ. Returns the number of
/// dynamic discards verified (so callers can assert non-vacuity).
fn check_dynamic_safety(
    ds: &Dataset,
    solver: SolverKind,
    rule: RuleKind,
    plan: &PathPlan,
    recheck: usize,
) -> usize {
    let opts = PathOptions {
        solver,
        cd: tight(),
        fista: sasvi::solver::FistaOptions {
            max_iters: 10_000,
            tol: 1e-13,
            lipschitz: None,
        },
        dynamic: DynamicOptions::enabled_every(recheck),
        ..Default::default()
    };
    let r = run_path_keep_betas(ds, plan, rule, opts);
    let traces = r.dynamic.as_ref().expect("dynamic traces must be retained");
    assert_eq!(traces.len(), plan.len());
    let mut verified = 0usize;
    for (step, trace) in plan.lambdas.iter().zip(traces.iter()) {
        if trace.dropped_total() == 0 {
            continue;
        }
        let exact = solve_exact(ds, *step);
        for (ci, ev) in trace.events.iter().enumerate() {
            for &j in &ev.dropped {
                assert!(
                    exact[j].abs() < 1e-10,
                    "{solver:?}/{rule:?} ({}): checkpoint {ci} (epoch {}) at \
                     lam/lmax={:.3} dropped feature {j}, but the exact solution \
                     has beta_j = {:e}",
                    ds.x.storage(),
                    ev.epoch,
                    step / plan.lambda_max,
                    exact[j]
                );
                verified += 1;
            }
        }
        // width bookkeeping is internally consistent
        for ev in &trace.events {
            assert_eq!(ev.width_before - ev.dropped.len(), ev.width_after);
        }
    }
    verified
}

#[test]
fn dynamic_safety_cd_dense_and_sparse_linear_grid() {
    for seed in [1u64, 12] {
        let (dn, sp) = backend_pair(seed);
        for ds in [&dn, &sp] {
            let plan = PathPlan::linear_spaced(ds, 10, 0.05);
            let v = check_dynamic_safety(ds, SolverKind::Cd, RuleKind::Sasvi, &plan, 3);
            assert!(v > 0, "seed {seed} ({}): no dynamic discards", ds.x.storage());
        }
    }
}

#[test]
fn dynamic_safety_cd_log_grid() {
    let (dn, sp) = backend_pair(5);
    for ds in [&dn, &sp] {
        let plan = PathPlan::log_spaced(ds, 10, 0.05);
        let v = check_dynamic_safety(ds, SolverKind::Cd, RuleKind::Sasvi, &plan, 4);
        assert!(v > 0, "{}: no dynamic discards", ds.x.storage());
    }
}

#[test]
fn dynamic_safety_fista_dense_and_sparse() {
    let (dn, sp) = backend_pair(7);
    for ds in [&dn, &sp] {
        let plan = PathPlan::linear_spaced(ds, 8, 0.1);
        let v = check_dynamic_safety(ds, SolverKind::Fista, RuleKind::Sasvi, &plan, 5);
        assert!(v > 0, "{}: no dynamic discards", ds.x.storage());
    }
}

#[test]
fn dynamic_safety_without_a_pathwise_rule() {
    // pure dynamic screening: the prior "safe set" is all of {0..p}, so
    // every checkpoint certifies against the full problem directly
    let (dn, sp) = backend_pair(9);
    for ds in [&dn, &sp] {
        let plan = PathPlan::linear_spaced(ds, 8, 0.1);
        let v = check_dynamic_safety(ds, SolverKind::Cd, RuleKind::None, &plan, 3);
        assert!(v > 0, "{}: no dynamic discards", ds.x.storage());
    }
}

// ---------------------------------------------------------------------------
// ISSUE 10: the penalty axis. Dynamic checkpoints inside the elastic-net
// and sparse-group-lasso solvers obey the same per-checkpoint contract:
// every feature a checkpoint discards is zero in a high-precision
// unscreened penalty-native solve at that step's λ — and for SGL, drops
// happen in whole groups, so the WHOLE group is zero (|β_g|_inf < 1e-10).
// ---------------------------------------------------------------------------

use sasvi::penalty::{GroupSpec, Penalty};
use sasvi::solver::cd::solve_cd_en;
use sasvi::solver::sgl::solve_sgl;

/// High-precision unscreened solve under the given penalty.
fn solve_exact_pen(ds: &Dataset, lam: f64, pen: &Penalty) -> Vec<f64> {
    let norms = ds.x.col_norms_sq();
    let mut beta = vec![0.0; ds.p()];
    let mut resid = ds.y.clone();
    match pen {
        Penalty::L1 => return solve_exact(ds, lam),
        Penalty::ElasticNet { alpha } => {
            let active: Vec<usize> = (0..ds.p()).collect();
            solve_cd_en(
                &ds.x, &ds.y, lam, *alpha, &active, &norms, &mut beta, &mut resid,
                &tight(),
            );
        }
        Penalty::SparseGroupLasso { groups, tau } => {
            let mut active_groups: Vec<usize> =
                (0..groups.n_groups(ds.p())).collect();
            solve_sgl(
                &ds.x, &ds.y, lam, *tau, *groups, &mut active_groups, &norms,
                &mut beta, &mut resid, &tight(), &DynamicOptions::off(),
            );
        }
    }
    beta
}

#[test]
fn dynamic_safety_penalty_axis() {
    let sgl_groups = GroupSpec::new(8);
    for pen in [
        Penalty::ElasticNet { alpha: 0.3 },
        Penalty::SparseGroupLasso { groups: sgl_groups, tau: 0.5 },
    ] {
        let (dn, sp) = backend_pair(15);
        for ds in [&dn, &sp] {
            let p = ds.p();
            let plan = PathPlan::linear_spaced(ds, 10, 0.05);
            let opts = PathOptions {
                cd: tight(),
                dynamic: DynamicOptions::enabled_every(3),
                penalty: pen,
                ..Default::default()
            };
            let r = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts);
            let traces = r.dynamic.as_ref().expect("dynamic traces must be retained");
            assert_eq!(traces.len(), plan.len());
            let mut verified = 0usize;
            for (step, trace) in plan.lambdas.iter().zip(traces.iter()) {
                if trace.dropped_total() == 0 {
                    continue;
                }
                let exact = solve_exact_pen(ds, *step, &pen);
                for (ci, ev) in trace.events.iter().enumerate() {
                    for &j in &ev.dropped {
                        assert!(
                            exact[j].abs() < 1e-10,
                            "{} ({}): checkpoint {ci} at lam/lmax={:.3} dropped \
                             feature {j}, but the exact solution has beta_j = {:e}",
                            pen.spec(),
                            ds.x.storage(),
                            step / plan.lambda_max,
                            exact[j]
                        );
                        // SGL drops whole groups: the group stays zero end
                        // to end, not just the dropped coordinate
                        if let Penalty::SparseGroupLasso { groups, .. } = &pen {
                            let g = groups.group_of(j);
                            let linf = exact[groups.range(g, p)]
                                .iter()
                                .fold(0.0f64, |m, b| m.max(b.abs()));
                            assert!(
                                linf < 1e-10,
                                "sgl ({}): dropped feature {j} of group {g} but \
                                 |beta_g|_inf = {linf:e}",
                                ds.x.storage()
                            );
                        }
                        verified += 1;
                    }
                }
            }
            assert!(
                verified > 0,
                "{} ({}): no dynamic discards — vacuous",
                pen.spec(),
                ds.x.storage()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// edge cases: degenerate inputs must degrade gracefully, never panic
// ---------------------------------------------------------------------------

#[test]
fn edge_lambda_at_and_above_lambda_max() {
    let ds = SyntheticSpec { n: 30, p: 60, nnz: 6, ..Default::default() }.generate(3);
    let pre = ds.precompute();
    for lam in [pre.lambda_max, 1.5 * pre.lambda_max] {
        let mut active: Vec<usize> = (0..ds.p()).collect();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        let (stats, trace) = solve_cd_dynamic(
            &ds.x, &ds.y, lam, &mut active, &pre.col_norms_sq, &pre.xty,
            &mut beta, &mut resid, &CdOptions::default(),
            &DynamicOptions::enabled_every(5),
        );
        assert!(stats.converged);
        assert!(beta.iter().all(|&b| b == 0.0));
        assert_eq!(trace.events[0].epoch, 0, "checkpoint must fire at epoch 0");
        // strictly above lambda_max everything goes at epoch 0; at exactly
        // lambda_max only the argmax feature(s) may survive
        assert!(
            trace.events[0].width_after <= 2,
            "lam={lam}: width after epoch-0 screen = {}",
            trace.events[0].width_after
        );
    }
}

#[test]
fn edge_zero_residual_warm_start() {
    // y = X beta0 exactly: the epoch-0 checkpoint sees r = 0 and must not
    // panic or produce non-finite state
    let ds = SyntheticSpec { n: 25, p: 50, nnz: 5, ..Default::default() }.generate(6);
    let mut beta = vec![0.0; ds.p()];
    beta[4] = 0.75;
    beta[31] = -1.25;
    let mut y = vec![0.0; ds.n()];
    ds.x.matvec(&beta, &mut y);
    let mut resid = vec![0.0; ds.n()];
    let mut xty = vec![0.0; ds.p()];
    ds.x.t_matvec(&y, &mut xty);
    let norms = ds.x.col_norms_sq();
    let mut active: Vec<usize> = (0..ds.p()).collect();
    let lam = 0.1 * sasvi::linalg::ops::inf_norm(&xty);
    let (stats, trace) = solve_cd_dynamic(
        &ds.x, &y, lam, &mut active, &norms, &xty, &mut beta, &mut resid,
        &CdOptions::default(), &DynamicOptions::enabled_every(2),
    );
    assert!(beta.iter().all(|b| b.is_finite()));
    assert!(resid.iter().all(|r| r.is_finite()));
    assert!(trace.events.iter().all(|e| e.gap.is_finite()));
    assert!(stats.epochs > 0);
}

#[test]
fn edge_single_column_path() {
    let x: sasvi::linalg::DesignMatrix =
        sasvi::linalg::DenseMatrix::from_fn(8, 1, |i, _| ((i % 3) as f64 + 1.0) / 3.0)
            .into();
    let y: Vec<f64> = (0..8).map(|i| (i as f64) * 0.2 - 0.7).collect();
    let ds = Dataset { name: "one-col".into(), x, y, beta_true: None, seed: 0 };
    let plan = PathPlan::linear_spaced(&ds, 6, 0.2);
    for solver in [SolverKind::Cd, SolverKind::Fista] {
        let opts = PathOptions {
            solver,
            dynamic: DynamicOptions::enabled_every(2),
            ..Default::default()
        };
        let r = sasvi::coordinator::run_path(&ds, &plan, RuleKind::Sasvi, opts);
        assert!(r.beta_final.iter().all(|b| b.is_finite()));
        assert_eq!(r.steps.len(), 6);
    }
}

#[test]
fn edge_recheck_cadence_zero_and_huge() {
    let ds = SyntheticSpec { n: 30, p: 80, nnz: 8, ..Default::default() }.generate(11);
    let plan = PathPlan::linear_spaced(&ds, 8, 0.1);
    let base = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, PathOptions::default());
    for recheck in [0usize, usize::MAX] {
        let opts = PathOptions {
            dynamic: DynamicOptions { enabled: true, recheck_every: recheck },
            ..Default::default()
        };
        let r = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, opts);
        // recheck = 0 degrades to the static solver (no checkpoints at
        // all); a huge cadence runs only the epoch-0 checkpoint — both
        // must complete and agree with the static path
        let a = base.betas.as_ref().unwrap();
        let b = r.betas.as_ref().unwrap();
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            for j in 0..ds.p() {
                assert!(
                    (x[j] - y[j]).abs() < 1e-6,
                    "recheck={recheck} step {k} feature {j}"
                );
            }
        }
        if recheck == 0 {
            assert_eq!(r.total_dynamic_dropped(), 0);
            assert!(r.steps.iter().all(|s| s.dyn_rechecks == 0));
        } else {
            assert!(r.steps.iter().all(|s| s.dyn_rechecks <= 1));
        }
    }
}
