//! Fairness and starvation battery for the work-stealing block scheduler
//! and the job pool above it.
//!
//! The scenario the scheduler exists for (ISSUE 8 / the ROADMAP's
//! "remaining leg of serving at scale"): the paper's serving story —
//! "one needs to try several regularization parameters" — means many
//! concurrent path jobs of wildly different sizes share one process-wide
//! lane pool. Under the old single-queue dispatch, a huge job's queued
//! lane tasks could strand a tiny job's behind them (head-of-line
//! blocking). With the steal registry, helper lanes re-pick the
//! least-served live dispatch at block granularity, so tiny dispatches
//! get helper service while a huge dispatch is mid-flight, no dispatch
//! starves, and a panicking kernel poisons nothing but its own caller.
//!
//! These tests run in the CI threads matrix (`SASVI_THREADS` 1 and 4):
//! every bound below must hold at any lane count, so wall-clock bounds
//! are deliberately generous — the sharp assertions are structural
//! (helper participation, termination, exactness), not timing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sasvi::coordinator::pool::{JobPool, JobSpec};
use sasvi::coordinator::{PathOptions, PathPlan};
use sasvi::data::synthetic::SyntheticSpec;
use sasvi::data::Dataset;
use sasvi::linalg::par::ThreadPool;
use sasvi::screening::RuleKind;

/// Wall-clock ceiling for work that should take milliseconds. Generous
/// enough for a loaded 2-core CI runner; small enough that a genuine
/// head-of-line stall (which scales with the *big* job's runtime) trips it.
const TINY_BOUND: Duration = Duration::from_secs(10);

fn dataset(seed: u64, n: usize, p: usize, nnz: usize) -> Arc<Dataset> {
    Arc::new(SyntheticSpec { n, p, nnz, ..Default::default() }.generate(seed))
}

fn lasso_job(ds: &Arc<Dataset>, k: usize, tag: &str) -> JobSpec {
    JobSpec::lasso(
        Arc::clone(ds),
        PathPlan::linear_spaced(ds, k, 0.1),
        RuleKind::Sasvi,
        PathOptions::default(),
        tag,
    )
}

/// Scheduler level: while one huge dispatch occupies the pool, a stream of
/// tiny dispatches issued from another thread must (a) each finish inside
/// a bound that does *not* scale with the huge job's runtime, and (b)
/// collectively receive helper-lane service — blocks of tiny dispatches
/// executed by threads other than their caller — which is exactly what the
/// single-queue design could not deliver.
#[test]
fn tiny_dispatches_are_served_while_a_huge_dispatch_runs() {
    let pool = ThreadPool::new(4);
    let steals_before = pool.steal_count();

    std::thread::scope(|scope| {
        // the huge job: 600 blocks x ~1ms, enough runway that the tiny
        // stream below runs entirely in its shadow
        let big = scope.spawn(|| {
            let done = AtomicU64::new(0);
            pool.for_blocks(600, 1, 4, |_, _| {
                std::thread::sleep(Duration::from_millis(1));
                done.fetch_add(1, Ordering::Relaxed);
            });
            done.load(Ordering::Relaxed)
        });
        // give the huge dispatch a head start so its helpers are attached
        std::thread::sleep(Duration::from_millis(30));

        // the tiny stream: 25 dispatches x 12 blocks x ~1ms each
        let caller = std::thread::current().id();
        let mut foreign_blocks = 0u64;
        for round in 0..25u64 {
            let t0 = Instant::now();
            let owners = pool.map_blocks(12, 1, 4, |_, _| {
                std::thread::sleep(Duration::from_millis(1));
                std::thread::current().id()
            });
            let dt = t0.elapsed();
            assert!(
                dt < TINY_BOUND,
                "tiny dispatch {round} took {dt:?} — starved behind the huge job"
            );
            foreign_blocks +=
                owners.iter().filter(|&&id| id != caller).count() as u64;
        }
        assert!(
            foreign_blocks > 0,
            "no tiny-dispatch block ever ran on a helper lane: \
             the scheduler never rebalanced away from the huge job"
        );

        // the huge job was not sacrificed: every one of its blocks ran
        assert_eq!(big.join().unwrap(), 600);
    });

    assert!(
        pool.steal_count() > steals_before,
        "steal counter must account helper-lane blocks"
    );
}

/// A dispatch whose lane budget is 1 must run strictly serial — on the
/// calling thread only — even with helpers idling. This is the lease
/// floor `coordinator::pool` relies on under worker oversubscription.
#[test]
fn lane_budget_of_one_runs_on_the_caller_only() {
    let pool = ThreadPool::new(4);
    let caller = std::thread::current().id();
    let owners = pool.map_blocks(40, 1, 1, |_, _| {
        std::thread::sleep(Duration::from_micros(200));
        std::thread::current().id()
    });
    assert!(owners.iter().all(|&id| id == caller));
}

/// Panic isolation under concurrency, via the public API only: dispatch
/// A's kernel panics mid-flight while dispatch B shares the scheduler.
/// The panic must re-raise on A's caller alone; B must complete with an
/// exact result; the pool must stay usable. (The old single-queue pool's
/// `expect("sasvi-par pool disconnected")` send path is structurally gone
/// — registration is a registry push that cannot fail — so dispatching
/// after a foreign panic must also never panic spuriously.)
#[test]
fn panicking_dispatch_poisons_nothing_but_its_own_caller() {
    let pool = ThreadPool::new(4);
    std::thread::scope(|scope| {
        let a = scope.spawn(|| {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.for_blocks(2000, 4, 4, |b, _| {
                    std::thread::sleep(Duration::from_micros(100));
                    assert!(b != 30, "kernel bug under concurrency");
                });
            }))
        });
        let b = scope.spawn(|| {
            let sums = pool.map_blocks(300, 4, 4, |_, r| {
                std::thread::sleep(Duration::from_micros(100));
                r.map(|i| i as u64).sum::<u64>()
            });
            sums.into_iter().sum::<u64>()
        });
        assert!(
            a.join().expect("dispatcher thread must survive").is_err(),
            "the kernel panic must re-raise on its own dispatcher"
        );
        assert_eq!(
            b.join().expect("concurrent dispatch was poisoned"),
            (0..300u64).sum::<u64>(),
            "concurrent dispatch must still be exact"
        );
    });
    // the scheduler survives: a fresh dispatch on the same pool completes
    let total: usize = pool.map_blocks(500, 16, 4, |_, r| r.len()).into_iter().sum();
    assert_eq!(total, 500);
}

/// Job-pool level: one long PATH job saturating a worker plus a stream of
/// tiny jobs on the other. Every tiny job must terminate well before the
/// long job's horizon (the lane leases keep the long job from hoarding the
/// block engine), and every job — long one included — must terminate.
#[test]
fn tiny_jobs_terminate_promptly_beside_a_long_path_job() {
    let big_ds = dataset(7, 60, 1500, 20);
    let tiny_ds = dataset(8, 15, 40, 4);

    let pool = JobPool::new(2, 32);
    let long_id = pool.submit(lasso_job(&big_ds, 40, "long")).unwrap();

    let mut tiny_waits = Vec::new();
    for i in 0..10 {
        let id = pool.submit(lasso_job(&tiny_ds, 2, &format!("tiny{i}"))).unwrap();
        let t0 = Instant::now();
        let res = pool.wait(id);
        let dt = t0.elapsed();
        assert!(res.is_some(), "tiny job {i} lost");
        assert!(
            res.unwrap().into_lasso().is_some(),
            "tiny job {i} came back as the wrong workload"
        );
        assert!(dt < TINY_BOUND, "tiny job {i} starved: {dt:?}");
        tiny_waits.push(dt);
    }

    let long_res = pool.wait(long_id);
    assert!(long_res.is_some(), "the long job must terminate too");
    assert_eq!(long_res.unwrap().into_lasso().unwrap().steps.len(), 40);
    pool.shutdown();
}

/// Saturation: more concurrent jobs than workers than lanes. All must
/// terminate, and the pool must drain — no deadlock between the fair
/// lane leases and the steal scheduler under full oversubscription.
#[test]
fn oversubscribed_pool_drains_completely() {
    let ds = dataset(11, 20, 80, 6);
    let pool = JobPool::new(4, 8);
    let specs: Vec<JobSpec> =
        (0..12).map(|i| lasso_job(&ds, 4, &format!("j{i}"))).collect();
    let t0 = Instant::now();
    let results = pool.run_all(specs);
    assert_eq!(results.len(), 12);
    for (i, r) in results.into_iter().enumerate() {
        let r = r.unwrap_or_else(|| panic!("job {i} failed or was lost"));
        assert_eq!(r.into_lasso().unwrap().steps.len(), 4);
    }
    assert!(
        t0.elapsed() < Duration::from_secs(120),
        "oversubscribed drain took implausibly long"
    );
    pool.shutdown();
}
