//! Edge-case and failure-injection tests across the stack.

use sasvi::coordinator::{run_path, JobPool, JobSpec, PathOptions, PathPlan};
use sasvi::data::synthetic::SyntheticSpec;
use sasvi::data::Dataset;
use sasvi::linalg::DenseMatrix;
use sasvi::screening::sasvi::feature_bounds;
use sasvi::screening::{Geometry, RuleKind, ScreenContext};
use sasvi::solver::cd::{solve_cd, CdOptions};
use sasvi::solver::DualState;
use std::sync::Arc;

/// A dataset with an all-zero column must be screened, never solved on, and
/// must not produce NaNs anywhere.
#[test]
fn zero_column_is_harmless() {
    let mut ds = SyntheticSpec { n: 20, p: 30, nnz: 4, ..Default::default() }
        .generate(3);
    ds.x.as_dense_mut().unwrap().col_mut(7).fill(0.0);
    let pre = ds.precompute();
    assert_eq!(pre.col_norms_sq[7], 0.0);
    let plan = PathPlan::linear_spaced(&ds, 10, 0.1);
    for rule in [RuleKind::None, RuleKind::Sasvi, RuleKind::Strong] {
        let r = run_path(&ds, &plan, rule, PathOptions::default());
        assert!(r.beta_final[7] == 0.0);
        assert!(r.beta_final.iter().all(|b| b.is_finite()));
    }
}

/// A sparse dataset with an empty (all-zero) column behaves like the dense
/// zero-column case: screened, never solved on, no NaNs.
#[test]
fn sparse_empty_column_is_harmless() {
    use sasvi::linalg::CscMatrix;
    let x = CscMatrix::from_triplets(
        4,
        3,
        &[(0, 0, 1.0), (2, 0, -2.0), (1, 2, 0.5), (3, 2, 1.5)],
    );
    let y = vec![1.0, -0.5, 2.0, 0.25];
    let ds = Dataset {
        name: "sparse-zero-col".into(),
        x: x.into(),
        y,
        beta_true: None,
        seed: 0,
    };
    let pre = ds.precompute();
    assert_eq!(pre.col_norms_sq[1], 0.0);
    let plan = PathPlan::linear_spaced(&ds, 6, 0.1);
    for rule in [RuleKind::None, RuleKind::Sasvi, RuleKind::Strong] {
        let r = run_path(&ds, &plan, rule, PathOptions::default());
        assert_eq!(r.beta_final[1], 0.0);
        assert!(r.beta_final.iter().all(|b| b.is_finite()));
    }
}

/// Duplicate columns: both get identical bounds; screening keeps or drops
/// them together.
#[test]
fn duplicate_columns_treated_identically() {
    let mut ds = SyntheticSpec { n: 25, p: 40, nnz: 5, ..Default::default() }
        .generate(5);
    let dense = ds.x.as_dense_mut().unwrap();
    let col3 = dense.col(3).to_vec();
    dense.col_mut(21).copy_from_slice(&col3);
    let pre = ds.precompute();
    let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
    let st = DualState::at_lambda_max(&ds.x, &ds.y, pre.lambda_max, &pre.xty);
    let mut bounds = vec![0.0; ds.p()];
    RuleKind::Sasvi
        .build()
        .bounds(&ctx, &st, 0.7 * pre.lambda_max, &mut bounds);
    assert!((bounds[3] - bounds[21]).abs() < 1e-12);
}

/// A response orthogonal to every feature: lambda_max = 0-ish; the path
/// must not panic and all solutions stay zero.
#[test]
fn orthogonal_response_degenerate_path() {
    let n = 8;
    // features only touch coordinates 0..4, response lives in 4..8
    let x = DenseMatrix::from_fn(n, 6, |i, j| {
        if i < 4 { ((i * 7 + j * 3) % 5) as f64 - 2.0 } else { 0.0 }
    });
    let y: Vec<f64> = (0..n).map(|i| if i >= 4 { 1.0 } else { 0.0 }).collect();
    let ds = Dataset { name: "orth".into(), x: x.into(), y, beta_true: None, seed: 0 };
    let lam_max = ds.lambda_max();
    assert!(lam_max.abs() < 1e-12);
    // grid needs positive lambdas; use a tiny custom grid above zero
    let plan = PathPlan::custom(vec![1.0, 0.5, 0.25], 1.0);
    let r = run_path(&ds, &plan, RuleKind::Sasvi, PathOptions::default());
    assert!(r.beta_final.iter().all(|&b| b == 0.0));
}

/// Theorem-3 formulas at extreme lambda ratios stay finite and ordered.
#[test]
fn bounds_finite_at_extreme_ratios() {
    let ds = SyntheticSpec { n: 15, p: 25, nnz: 3, ..Default::default() }
        .generate(9);
    let pre = ds.precompute();
    let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
    let st = DualState::at_lambda_max(&ds.x, &ds.y, pre.lambda_max, &pre.xty);
    for frac in [0.999_999, 0.5, 1e-3, 1e-6] {
        let g = Geometry::compute(&ctx, &st, frac * pre.lambda_max);
        for j in 0..ds.p() {
            let (up, um) = feature_bounds(&g, st.xt_theta[j], pre.xty[j],
                                          pre.col_norms_sq[j]);
            assert!(up.is_finite() && um.is_finite(), "frac={frac} j={j}");
            // theta1 is in Omega: bounds dominate its inner products
            assert!(up >= st.xt_theta[j] - 1e-9);
            assert!(um >= -st.xt_theta[j] - 1e-9);
        }
    }
}

/// Warm-start eviction: a feature active at lambda_1 that gets screened at
/// lambda_2 must have its residual contribution restored exactly.
#[test]
fn screened_warm_start_keeps_residual_consistent() {
    let ds = SyntheticSpec { n: 30, p: 60, nnz: 10, ..Default::default() }
        .generate(11);
    let plan = PathPlan::linear_spaced(&ds, 25, 0.05);
    let r = sasvi::coordinator::run_path_keep_betas(
        &ds, &plan, RuleKind::Sasvi, PathOptions::default(),
    );
    // recompute residuals from scratch at each step; objective must match
    // a fresh high-precision solve
    let pre = ds.precompute();
    let betas = r.betas.as_ref().unwrap();
    for (k, lam) in plan.lambdas.iter().enumerate().step_by(6) {
        let mut fresh_beta = vec![0.0; ds.p()];
        let mut fresh_resid = ds.y.clone();
        let active: Vec<usize> = (0..ds.p()).collect();
        let opts = CdOptions { tol: 1e-12, gap_tol: 1e-12, ..Default::default() };
        solve_cd(&ds.x, &ds.y, *lam, &active, &pre.col_norms_sq,
                 &mut fresh_beta, &mut fresh_resid, &opts);
        for j in 0..ds.p() {
            assert!(
                (betas[k][j] - fresh_beta[j]).abs() < 1e-5,
                "step {k} feature {j}"
            );
        }
    }
}

/// The degenerate datasets above must also survive *dynamic* screening:
/// a zero column is dropped by the first checkpoint (its bound is 0), and
/// an orthogonal response never produces NaNs in the checkpoint geometry.
#[test]
fn dynamic_screening_handles_degenerate_datasets() {
    use sasvi::screening::dynamic::DynamicOptions;
    // zero column
    let mut ds = SyntheticSpec { n: 20, p: 30, nnz: 4, ..Default::default() }
        .generate(3);
    ds.x.as_dense_mut().unwrap().col_mut(7).fill(0.0);
    let plan = PathPlan::linear_spaced(&ds, 8, 0.1);
    let opts = PathOptions {
        dynamic: DynamicOptions::enabled_every(2),
        ..Default::default()
    };
    for rule in [RuleKind::None, RuleKind::Sasvi, RuleKind::Strong] {
        let r = run_path(&ds, &plan, rule, opts);
        assert_eq!(r.beta_final[7], 0.0);
        assert!(r.beta_final.iter().all(|b| b.is_finite()));
    }
    // orthogonal response (lambda_max ~ 0, custom positive grid)
    let n = 8;
    let x = DenseMatrix::from_fn(n, 6, |i, j| {
        if i < 4 { ((i * 7 + j * 3) % 5) as f64 - 2.0 } else { 0.0 }
    });
    let y: Vec<f64> = (0..n).map(|i| if i >= 4 { 1.0 } else { 0.0 }).collect();
    let ds = Dataset { name: "orth-dyn".into(), x: x.into(), y, beta_true: None, seed: 0 };
    let plan = PathPlan::custom(vec![1.0, 0.5, 0.25], 1.0);
    let r = run_path(&ds, &plan, RuleKind::Sasvi, opts);
    assert!(r.beta_final.iter().all(|&b| b == 0.0));
    assert!(r.total_dynamic_dropped() > 0, "zero-bound features must drop");
}

/// Pool backpressure: a 1-slot queue with a single worker still completes
/// a burst of jobs, in order, with no deadlock.
#[test]
fn pool_bounded_queue_no_deadlock() {
    let ds = Arc::new(
        SyntheticSpec { n: 12, p: 20, nnz: 2, ..Default::default() }.generate(2),
    );
    let pool = JobPool::new(1, 1);
    let mut ids = Vec::new();
    for _ in 0..8 {
        let spec = JobSpec::lasso(
            Arc::clone(&ds),
            PathPlan::linear_spaced(&ds, 4, 0.2),
            RuleKind::Sasvi,
            PathOptions::default(),
            "burst".into(),
        );
        ids.push(pool.submit(spec).expect("pool is live"));
    }
    for id in ids {
        assert!(pool.wait(id).is_some());
    }
}

/// Manifest with overlapping shapes: find() returns the exact match.
#[test]
fn manifest_shape_disambiguation() {
    let text = "\
artifact g_n8_p16\ngraph g\nfile a.hlo.txt\nn 8\np 16\nin f32 8,16\nout f32 16\nend
artifact g_n8_p32\ngraph g\nfile b.hlo.txt\nn 8\np 32\nin f32 8,32\nout f32 32\nend
";
    let m = sasvi::runtime::Manifest::parse(text).unwrap();
    assert_eq!(m.find("g", 8, 16).unwrap().file, "a.hlo.txt");
    assert_eq!(m.find("g", 8, 32).unwrap().file, "b.hlo.txt");
    assert!(m.find("g", 8, 64).is_none());
}

/// n = 1 (single sample) degenerate but valid.
#[test]
fn single_sample_path() {
    let x = DenseMatrix::from_fn(1, 5, |_, j| (j as f64 + 1.0) / 5.0);
    let y = vec![2.0];
    let ds = Dataset { name: "n1".into(), x: x.into(), y, beta_true: None, seed: 0 };
    let plan = PathPlan::linear_spaced(&ds, 5, 0.2);
    let r = run_path(&ds, &plan, RuleKind::Sasvi, PathOptions::default());
    assert!(r.beta_final.iter().all(|b| b.is_finite()));
    // with one sample only one feature can be active at the end
    let nnz = r.steps.last().unwrap().nnz;
    assert!(nnz <= 1, "nnz {nnz}");
}
