//! The paper's "safe" guarantee, tested per-rule along a λ-path.
//!
//! For every grid point λ_k (descending): build the dual state from a
//! high-precision *unscreened* solve at λ_{k-1}, screen with each rule,
//! then verify in a high-precision unscreened solve at λ_k that every
//! screened-out feature is numerically zero (|β_j| < 1e-10).
//!
//! The three safe rules (SAFE, DPP, Sasvi) must pass raw — that is
//! Theorem 3 / §3 of the paper. The strong rule is a heuristic whose raw
//! discards *may* be wrong by design, so for it the guarantee under test
//! is the coordinator's: after KKT correction, the screened-out set is
//! consistent with the reference solution (and the corrected path equals
//! the unscreened path).
//!
//! Runs on both storage backends — sparse synthetic CSC and its densified
//! twin — since rule evaluation consumes backend-computed statistics.

use sasvi::coordinator::{run_path_keep_betas, PathOptions, PathPlan};
use sasvi::data::synthetic::SyntheticSpec;
use sasvi::data::Dataset;
use sasvi::screening::{RuleKind, ScreenContext};
use sasvi::solver::cd::{solve_cd, CdOptions};
use sasvi::solver::DualState;

fn tight() -> CdOptions {
    CdOptions {
        max_epochs: 30_000,
        tol: 1e-13,
        gap_tol: 1e-13,
        ..Default::default()
    }
}

/// High-precision unscreened solve; returns (beta, residual).
fn solve_exact(ds: &Dataset, lam: f64) -> (Vec<f64>, Vec<f64>) {
    let active: Vec<usize> = (0..ds.p()).collect();
    let norms = ds.x.col_norms_sq();
    let mut beta = vec![0.0; ds.p()];
    let mut resid = ds.y.clone();
    solve_cd(&ds.x, &ds.y, lam, &active, &norms, &mut beta, &mut resid, &tight());
    (beta, resid)
}

fn backend_pair(seed: u64) -> (Dataset, Dataset) {
    let sp = SyntheticSpec {
        n: 40,
        p: 300,
        nnz: 25,
        density: 0.15,
        ..Default::default()
    }
    .generate(seed);
    assert!(sp.x.is_sparse());
    let mut dn = sp.clone();
    dn.x = sp.x.to_dense().into();
    (dn, sp)
}

/// Raw per-step safety for one safe rule on one dataset.
fn check_rule_safety_along_path(ds: &Dataset, rule_kind: RuleKind) {
    let pre = ds.precompute();
    let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
    let rule = rule_kind.build();
    assert!(rule.is_safe(), "{rule_kind:?} must declare itself safe");
    // descending grid: 0.95, 0.85, ..., 0.15 of lambda_max
    let fracs: Vec<f64> = (0..9).map(|k| 0.95 - 0.1 * k as f64).collect();
    let mut total_screened = 0usize;
    for w in fracs.windows(2) {
        let lam1 = w[0] * pre.lambda_max;
        let lam2 = w[1] * pre.lambda_max;
        let (_, resid1) = solve_exact(ds, lam1);
        let state = DualState::from_residual(&ds.x, &resid1, lam1);
        let mut keep = vec![false; ds.p()];
        let outcome = rule.screen(&ctx, &state, lam2, &mut keep);
        total_screened += outcome.screened;
        let (beta2, _) = solve_exact(ds, lam2);
        for j in 0..ds.p() {
            if !keep[j] {
                assert!(
                    beta2[j].abs() < 1e-10,
                    "{rule_kind:?} ({}) screened feature {j} at lam2/lmax = {:.2} \
                     but the reference solution has beta_j = {:e}",
                    ds.x.storage(),
                    w[1],
                    beta2[j]
                );
            }
        }
    }
    assert!(
        total_screened > 0,
        "{rule_kind:?} ({}) screened nothing along the whole path — vacuous test",
        ds.x.storage()
    );
}

#[test]
fn safe_rule_safety_dense_and_sparse() {
    for seed in [1u64, 8] {
        let (dn, sp) = backend_pair(seed);
        check_rule_safety_along_path(&dn, RuleKind::Safe);
        check_rule_safety_along_path(&sp, RuleKind::Safe);
    }
}

#[test]
fn dpp_rule_safety_dense_and_sparse() {
    for seed in [2u64, 9] {
        let (dn, sp) = backend_pair(seed);
        check_rule_safety_along_path(&dn, RuleKind::Dpp);
        check_rule_safety_along_path(&sp, RuleKind::Dpp);
    }
}

#[test]
fn sasvi_rule_safety_dense_and_sparse() {
    for seed in [3u64, 10] {
        let (dn, sp) = backend_pair(seed);
        check_rule_safety_along_path(&dn, RuleKind::Sasvi);
        check_rule_safety_along_path(&sp, RuleKind::Sasvi);
    }
}

/// The strong rule's guarantee is post-correction: the coordinator re-admits
/// KKT violators, after which the path must equal the unscreened reference —
/// equivalently, every feature still screened out is zero in the reference.
#[test]
fn strong_rule_safety_after_kkt_correction() {
    for seed in [4u64, 11] {
        let (dn, sp) = backend_pair(seed);
        for ds in [&dn, &sp] {
            let plan = PathPlan::linear_spaced(ds, 14, 0.1);
            let opts = PathOptions {
                cd: tight(),
                // tight correction: re-admit even marginal violators so the
                // corrected path can be compared against the reference at a
                // strict bar
                kkt_tol: 1e-9,
                ..Default::default()
            };
            let reference = run_path_keep_betas(ds, &plan, RuleKind::None, opts);
            let corrected = run_path_keep_betas(ds, &plan, RuleKind::Strong, opts);
            let a = reference.betas.as_ref().unwrap();
            let b = corrected.betas.as_ref().unwrap();
            for (k, (ra, rb)) in a.iter().zip(b.iter()).enumerate() {
                for j in 0..ds.p() {
                    assert!(
                        (ra[j] - rb[j]).abs() < 1e-6,
                        "strong-rule path ({}) diverged at step {k} feature {j}: \
                         {} vs {}",
                        ds.x.storage(),
                        ra[j],
                        rb[j]
                    );
                }
            }
            // the rule must actually have screened something for this test
            // to mean anything
            let screened: usize = corrected.steps.iter().map(|s| s.screened).sum();
            assert!(screened > 0, "strong rule screened nothing ({})", ds.x.storage());
        }
    }
}

// ---------------------------------------------------------------------------
// ISSUE 10: the penalty axis. The pathwise screens for elastic net and
// sparse-group lasso are the gap-safe sequential tests (`rescreen_en` /
// `rescreen_sgl`) evaluated at the carried primal point, and their safety
// contract is the same as the ℓ1 rules': every screened-out feature (for
// SGL: every feature of a screened-out group) is numerically zero in a
// high-precision unscreened penalty-native solve at the new λ.
// ---------------------------------------------------------------------------

use sasvi::penalty::GroupSpec;
use sasvi::screening::dynamic::{rescreen_en, rescreen_sgl, DynamicOptions};
use sasvi::solver::cd::solve_cd_en;
use sasvi::solver::sgl::solve_sgl;

/// High-precision unscreened elastic-net solve; returns (beta, residual).
fn solve_exact_en(ds: &Dataset, lam: f64, alpha: f64) -> (Vec<f64>, Vec<f64>) {
    let active: Vec<usize> = (0..ds.p()).collect();
    let norms = ds.x.col_norms_sq();
    let mut beta = vec![0.0; ds.p()];
    let mut resid = ds.y.clone();
    solve_cd_en(
        &ds.x, &ds.y, lam, alpha, &active, &norms, &mut beta, &mut resid, &tight(),
    );
    (beta, resid)
}

/// High-precision unscreened sparse-group-lasso solve.
fn solve_exact_sgl(
    ds: &Dataset,
    lam: f64,
    tau: f64,
    groups: GroupSpec,
) -> (Vec<f64>, Vec<f64>) {
    let mut active_groups: Vec<usize> = (0..groups.n_groups(ds.p())).collect();
    let norms = ds.x.col_norms_sq();
    let mut beta = vec![0.0; ds.p()];
    let mut resid = ds.y.clone();
    solve_sgl(
        &ds.x, &ds.y, lam, tau, groups, &mut active_groups, &norms, &mut beta,
        &mut resid, &tight(), &DynamicOptions::off(),
    );
    (beta, resid)
}

#[test]
fn elastic_net_pathwise_screen_safety() {
    let alpha = 0.3;
    for seed in [5u64, 13] {
        let (dn, sp) = backend_pair(seed);
        for ds in [&dn, &sp] {
            let p = ds.p();
            let pre = ds.precompute();
            let all: Vec<usize> = (0..p).collect();
            let mut xt_r = vec![0.0; p];
            let fracs: Vec<f64> = (0..9).map(|k| 0.95 - 0.1 * k as f64).collect();
            let mut total_screened = 0usize;
            for w in fracs.windows(2) {
                let lam1 = w[0] * pre.lambda_max;
                let lam2 = w[1] * pre.lambda_max;
                let (beta1, resid1) = solve_exact_en(ds, lam1, alpha);
                let rs = rescreen_en(
                    &ds.x, &ds.y, lam2, alpha, &pre.xty, &pre.col_norms_sq, &all,
                    &beta1, &resid1, &mut xt_r,
                );
                let (beta2, _) = solve_exact_en(ds, lam2, alpha);
                for &j in &rs.dropped {
                    assert!(
                        beta2[j].abs() < 1e-10,
                        "en ({}) screened feature {j} at lam2/lmax = {:.2} but the \
                         reference solution has beta_j = {:e}",
                        ds.x.storage(),
                        w[1],
                        beta2[j]
                    );
                }
                total_screened += rs.dropped.len();
            }
            assert!(
                total_screened > 0,
                "en ({}) screened nothing along the whole path — vacuous",
                ds.x.storage()
            );
        }
    }
}

#[test]
fn sparse_group_lasso_pathwise_screen_group_zero_safety() {
    let tau = 0.5;
    let groups = GroupSpec::new(8);
    for seed in [6u64, 14] {
        let (dn, sp) = backend_pair(seed);
        for ds in [&dn, &sp] {
            let p = ds.p();
            let pre = ds.precompute();
            let all_groups: Vec<usize> = (0..groups.n_groups(p)).collect();
            let all_feats: Vec<usize> = (0..p).collect();
            let mut xt_r = vec![0.0; p];
            let fracs: Vec<f64> = (0..9).map(|k| 0.95 - 0.1 * k as f64).collect();
            let mut total_screened = 0usize;
            for w in fracs.windows(2) {
                let lam1 = w[0] * pre.lambda_max;
                let lam2 = w[1] * pre.lambda_max;
                let (beta1, resid1) = solve_exact_sgl(ds, lam1, tau, groups);
                let rs = rescreen_sgl(
                    &ds.x, &ds.y, lam2, tau, groups, &all_groups, &all_feats,
                    &pre.col_norms_sq, &beta1, &resid1, &mut xt_r,
                );
                let (beta2, _) = solve_exact_sgl(ds, lam2, tau, groups);
                for &g in &rs.dropped_groups {
                    // group-zero safety: the WHOLE screened group is zero
                    let linf = beta2[groups.range(g, p)]
                        .iter()
                        .fold(0.0f64, |m, b| m.max(b.abs()));
                    assert!(
                        linf < 1e-10,
                        "sgl ({}) screened group {g} at lam2/lmax = {:.2} but the \
                         reference solution has |beta_g|_inf = {linf:e}",
                        ds.x.storage(),
                        w[1],
                    );
                }
                total_screened += rs.dropped_groups.len();
            }
            assert!(
                total_screened > 0,
                "sgl ({}) screened no groups along the whole path — vacuous",
                ds.x.storage()
            );
        }
    }
}
