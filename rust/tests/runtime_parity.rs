//! Three-layer composition test: the AOT-compiled XLA artifacts (L1 Pallas
//! kernel inside the L2 JAX graphs) must reproduce the native Rust rules.
//!
//! Requires `make artifacts`. Tests no-op (with a note) when the artifact
//! directory is missing so `cargo test` works before the Python step.

use sasvi::data::synthetic::SyntheticSpec;
use sasvi::runtime::executor::to_rowmajor;
use sasvi::runtime::Runtime;
use sasvi::screening::{RuleKind, ScreenContext};
use sasvi::solver::cd::{solve_cd, CdOptions};
use sasvi::solver::DualState;

fn open_runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping");
        return None;
    }
    Some(Runtime::open("artifacts").expect("open artifacts"))
}

fn setup(n: usize, p: usize) -> (sasvi::data::Dataset, DualState, f64) {
    let ds = SyntheticSpec { n, p, nnz: p / 10, ..Default::default() }.generate(42);
    let pre = ds.precompute();
    let lam1 = 0.7 * pre.lambda_max;
    let active: Vec<usize> = (0..p).collect();
    let mut beta = vec![0.0; p];
    let mut resid = ds.y.clone();
    solve_cd(&ds.x, &ds.y, lam1, &active, &pre.col_norms_sq, &mut beta, &mut resid,
             &CdOptions::default());
    let st = DualState::from_residual(&ds.x, &resid, lam1);
    (ds, st, lam1)
}

#[test]
fn screen_graphs_match_native_rules() {
    let Some(rt) = open_runtime() else { return };
    let (n, p) = (64, 256);
    let (ds, st, lam1) = setup(n, p);
    let pre = ds.precompute();
    let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
    let x_rm = to_rowmajor(&ds.x);
    let lam2 = 0.5 * pre.lambda_max;

    for (graph, rule) in [
        ("sasvi_screen", RuleKind::Sasvi),
        ("safe_screen", RuleKind::Safe),
        ("dpp_screen", RuleKind::Dpp),
        ("strong_screen", RuleKind::Strong),
    ] {
        let (up, um, keep_xla) = rt
            .execute_screen(graph, &x_rm, n, p, &ds.y, &st.theta, lam1, lam2)
            .expect(graph);
        let mut bounds = vec![0.0; p];
        let rule_obj = rule.build();
        rule_obj.bounds(&ctx, &st, lam2, &mut bounds);
        let mut keep_native = vec![false; p];
        rule_obj.screen(&ctx, &st, lam2, &mut keep_native);

        let mut mismatches = 0;
        for j in 0..p {
            // XLA path runs in f32: compare with a loose tolerance and
            // count decision flips only outside a small indecision band.
            let native = bounds[j];
            let xla = if graph == "sasvi_screen" { up[j].max(um[j]) } else { up[j].max(um[j]) };
            let tol = 2e-3 * native.abs().max(1.0);
            assert!(
                (native - xla).abs() < tol.max(5e-3),
                "{graph} feature {j}: native bound {native} vs xla {xla}"
            );
            let keep_x = keep_xla[j] > 0.5;
            if keep_x != keep_native[j] && (native - 1.0).abs() > 1e-3 {
                mismatches += 1;
            }
        }
        assert_eq!(mismatches, 0, "{graph}: decision flips outside the f32 band");
    }
}

#[test]
fn fista_epoch_graph_solves_lasso() {
    let Some(rt) = open_runtime() else { return };
    let (n, p) = (64, 256);
    let ds = SyntheticSpec { n, p, nnz: 20, ..Default::default() }.generate(9);
    let pre = ds.precompute();
    let lam = 0.4 * pre.lambda_max;
    let lip = ds.x.spectral_norm_sq(100) * 1.01;
    let art = rt.find("fista_epoch", n, p).expect("fista artifact").clone();
    let x_rm = to_rowmajor(&ds.x);

    let mut beta = vec![0.0; p];
    let mut z = vec![0.0; p];
    let mut t = vec![1.0];
    let lam_l = [lam, lip];
    let mask = vec![1.0; p];
    let mut theta = vec![0.0; n];
    for _ in 0..30 {
        let out = rt
            .execute(&art, &[&x_rm, &ds.y, &beta, &z, &t, &lam_l, &mask])
            .expect("fista epoch");
        beta = out[0].clone();
        z = out[1].clone();
        t = out[2].clone();
        theta = out[3].clone();
    }
    // cross-check against the native CD solver
    let active: Vec<usize> = (0..p).collect();
    let mut beta_cd = vec![0.0; p];
    let mut resid = ds.y.clone();
    solve_cd(&ds.x, &ds.y, lam, &active, &pre.col_norms_sq, &mut beta_cd, &mut resid,
             &CdOptions::default());
    let mut max_err = 0.0f64;
    for j in 0..p {
        max_err = max_err.max((beta[j] - beta_cd[j]).abs());
    }
    assert!(max_err < 5e-3, "FISTA-in-XLA vs CD max err {max_err}");
    // theta returned by the graph should be near the scaled residual
    let mut max_terr = 0.0f64;
    for i in 0..n {
        max_terr = max_terr.max((theta[i] - resid[i] / lam).abs());
    }
    assert!(max_terr < 5e-3, "dual point mismatch {max_terr}");
}

#[test]
fn lasso_stats_graph_reports_gap() {
    let Some(rt) = open_runtime() else { return };
    let (n, p) = (64, 256);
    let ds = SyntheticSpec { n, p, nnz: 15, ..Default::default() }.generate(4);
    let pre = ds.precompute();
    let lam = 0.5 * pre.lambda_max;
    let active: Vec<usize> = (0..p).collect();
    let mut beta = vec![0.0; p];
    let mut resid = ds.y.clone();
    solve_cd(&ds.x, &ds.y, lam, &active, &pre.col_norms_sq, &mut beta, &mut resid,
             &CdOptions::default());
    let art = rt.find("lasso_stats", n, p).expect("stats artifact").clone();
    let x_rm = to_rowmajor(&ds.x);
    let out = rt.execute(&art, &[&x_rm, &ds.y, &beta, &[lam]]).expect("stats");
    let stats = &out[0];
    assert_eq!(stats.len(), 4);
    let (primal, dual, gap, infeas) = (stats[0], stats[1], stats[2], stats[3]);
    assert!(gap >= -1e-2, "gap {gap}");
    assert!(gap < 1e-2 * primal.max(1.0), "gap {gap} primal {primal}");
    assert!(infeas <= 1.0 + 1e-2, "infeas {infeas}");
    assert!(dual <= primal + 1e-3);
}

#[test]
fn power_iteration_graph_matches_native() {
    let Some(rt) = open_runtime() else { return };
    let (n, p) = (64, 256);
    let ds = SyntheticSpec { n, p, nnz: 10, ..Default::default() }.generate(2);
    let art = rt.find("power_iteration", n, p).expect("power artifact").clone();
    let x_rm = to_rowmajor(&ds.x);
    let v0 = vec![1.0; p];
    let out = rt.execute(&art, &[&x_rm, &v0]).expect("power iteration");
    let xla = out[0][0];
    let native = ds.x.spectral_norm_sq(200);
    assert!(
        (xla - native).abs() / native < 1e-2,
        "xla {xla} vs native {native}"
    );
}

#[test]
fn manifest_covers_all_graphs_and_shapes() {
    let Some(rt) = open_runtime() else { return };
    for graph in [
        "sasvi_screen", "safe_screen", "dpp_screen", "strong_screen",
        "fista_epoch", "lasso_stats", "power_iteration",
    ] {
        let shapes = rt.manifest().shapes(graph);
        assert!(!shapes.is_empty(), "graph {graph} missing from manifest");
        assert!(shapes.contains(&(64, 256)), "graph {graph} missing demo shape");
    }
}
