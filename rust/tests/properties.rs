//! Property-based tests over random Lasso instances (hand-rolled harness in
//! `sasvi::testutil` — no proptest offline).
//!
//! Invariants covered:
//!  * safety: any feature screened by a safe rule is zero in a
//!    high-precision solution at lambda_2;
//!  * dominance: Sasvi's kept set is a subset of DPP's (provable) and its
//!    screened count is >= SAFE's (empirical, §3);
//!  * path equality: every rule's path equals the no-screening path;
//!  * dual feasibility of every DualState the coordinator produces;
//!  * sure-removal soundness vs re-screening.

use sasvi::coordinator::{run_path_keep_betas, PathOptions, PathPlan};
use sasvi::screening::{RuleKind, ScreenContext};
use sasvi::solver::cd::{solve_cd, CdOptions};
use sasvi::solver::DualState;
use sasvi::testutil::{build_instance, forall, CaseParams};

fn solve_exact(
    ds: &sasvi::data::Dataset,
    lam: f64,
) -> (Vec<f64>, Vec<f64>) {
    let p = ds.p();
    let active: Vec<usize> = (0..p).collect();
    let norms = ds.x.col_norms_sq();
    let mut beta = vec![0.0; p];
    let mut resid = ds.y.clone();
    let opts = CdOptions {
        max_epochs: 20_000,
        tol: 1e-12,
        gap_tol: 1e-12,
        ..Default::default()
    };
    solve_cd(&ds.x, &ds.y, lam, &active, &norms, &mut beta, &mut resid, &opts);
    (beta, resid)
}

fn state_at(ds: &sasvi::data::Dataset, lam1: f64) -> DualState {
    let (_, resid) = solve_exact(ds, lam1);
    DualState::from_residual(&ds.x, &resid, lam1)
}

fn check_safety(case: &CaseParams, rule: RuleKind) -> Result<(), String> {
    let ds = build_instance(case);
    let pre = ds.precompute();
    let lam1 = case.frac1 * pre.lambda_max;
    let lam2 = case.frac2 * lam1;
    let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
    let st = state_at(&ds, lam1);
    let mut keep = vec![false; ds.p()];
    rule.build().screen(&ctx, &st, lam2, &mut keep);
    let (beta2, _) = solve_exact(&ds, lam2);
    for j in 0..ds.p() {
        if !keep[j] && beta2[j].abs() > 1e-8 {
            return Err(format!(
                "{:?} screened feature {j} but beta2[{j}] = {}",
                rule, beta2[j]
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_sasvi_is_safe() {
    forall(101, 40, 40, 120, |c| check_safety(c, RuleKind::Sasvi));
}

#[test]
fn prop_safe_rule_is_safe() {
    forall(102, 25, 35, 90, |c| check_safety(c, RuleKind::Safe));
}

#[test]
fn prop_dpp_is_safe() {
    forall(103, 25, 35, 90, |c| check_safety(c, RuleKind::Dpp));
}

#[test]
fn prop_sasvi_dominates_dpp_per_feature() {
    forall(104, 40, 40, 120, |case| {
        let ds = build_instance(case);
        let pre = ds.precompute();
        let lam1 = case.frac1 * pre.lambda_max;
        let lam2 = case.frac2 * lam1;
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let st = state_at(&ds, lam1);
        let mut k_sasvi = vec![false; ds.p()];
        let mut k_dpp = vec![false; ds.p()];
        RuleKind::Sasvi.build().screen(&ctx, &st, lam2, &mut k_sasvi);
        RuleKind::Dpp.build().screen(&ctx, &st, lam2, &mut k_dpp);
        for j in 0..ds.p() {
            if k_sasvi[j] && !k_dpp[j] {
                return Err(format!("feature {j}: Sasvi kept, DPP screened"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_path_equality_all_rules() {
    forall(105, 12, 35, 80, |case| {
        let ds = build_instance(case);
        let plan = PathPlan::linear_spaced(&ds, 8, 0.1);
        let base = run_path_keep_betas(&ds, &plan, RuleKind::None, PathOptions::default());
        let b0 = base.betas.as_ref().unwrap();
        for rule in [RuleKind::Sasvi, RuleKind::Strong] {
            let r = run_path_keep_betas(&ds, &plan, rule, PathOptions::default());
            let bs = r.betas.as_ref().unwrap();
            for (k, (a, b)) in b0.iter().zip(bs.iter()).enumerate() {
                for j in 0..ds.p() {
                    if (a[j] - b[j]).abs() > 1e-5 {
                        return Err(format!(
                            "{rule:?} step {k} feature {j}: {} vs {}",
                            a[j], b[j]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dual_states_feasible_along_path() {
    forall(106, 15, 35, 80, |case| {
        let ds = build_instance(case);
        let pre = ds.precompute();
        let plan = PathPlan::linear_spaced(&ds, 6, 0.1);
        // walk the path manually, checking feasibility of each dual state
        let norms = &pre.col_norms_sq;
        let active: Vec<usize> = (0..ds.p()).collect();
        let mut beta = vec![0.0; ds.p()];
        let mut resid = ds.y.clone();
        for &lam in &plan.lambdas {
            solve_cd(&ds.x, &ds.y, lam, &active, norms, &mut beta, &mut resid,
                     &CdOptions::default());
            let st = DualState::from_residual(&ds.x, &resid, lam);
            let infeas = st
                .xt_theta
                .iter()
                .fold(0.0f64, |m, v| m.max(v.abs()));
            if infeas > 1.0 + 1e-9 {
                return Err(format!("dual infeasible at lam {lam}: {infeas}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sure_removal_consistent_with_screening() {
    use sasvi::screening::sure_removal::SureRemovalAnalysis;
    forall(107, 15, 30, 60, |case| {
        let ds = build_instance(case);
        let pre = ds.precompute();
        let lam1 = case.frac1 * pre.lambda_max;
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let st = state_at(&ds, lam1);
        let analysis = SureRemovalAnalysis::new(&ctx, &st);
        let rule = RuleKind::Sasvi.build();
        // pick a handful of lambdas; a feature whose lam_s < lam must be
        // screened by the rule at lam (consistency of the two code paths)
        for frac in [0.95, 0.7, 0.45] {
            let lam2 = frac * lam1;
            let mut keep = vec![false; ds.p()];
            rule.screen(&ctx, &st, lam2, &mut keep);
            for j in 0..ds.p() {
                let rep = analysis.analyze(&ctx, &st, j, 0.01 * lam1);
                if rep.lam_s < lam2 * 0.999 && keep[j] {
                    return Err(format!(
                        "feature {j}: lam_s {} < lam2 {lam2} but rule kept it",
                        rep.lam_s
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sure_removal_is_monotone_and_grounded() {
    // Theorem 4 / §4: the sure-removal parameter lam_s(j) certifies that
    // feature j, once removed, *stays* removed at every lambda the path
    // visits inside (lam_s, lam1) — screening never flickers back on
    // within the certified interval — and the reference (unscreened,
    // high-precision) solution is zero at each such grid point.
    use sasvi::screening::sure_removal::SureRemovalAnalysis;
    use std::sync::atomic::{AtomicUsize, Ordering};
    let removable_total = AtomicUsize::new(0);
    forall(110, 10, 30, 60, |case| {
        let ds = build_instance(case);
        let pre = ds.precompute();
        let lam1 = case.frac1.max(0.4) * pre.lambda_max;
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let st = state_at(&ds, lam1);
        let analysis = SureRemovalAnalysis::new(&ctx, &st);
        let lam_min = 0.05 * lam1;
        let reports = analysis.analyze_all(&ctx, &st, lam_min);
        for (j, rep) in reports.iter().enumerate() {
            if rep.lam_s >= lam1 * 0.999 {
                continue; // never removable from this state
            }
            removable_total.fetch_add(1, Ordering::Relaxed);
            // contiguity: screened at EVERY lambda strictly inside
            // (lam_s, lam1) — walk a fine descending grid
            let lo = rep.lam_s.max(lam_min) * 1.001;
            let hi = lam1 * 0.999;
            if lo >= hi {
                continue;
            }
            for t in 0..32 {
                let lam = hi - (hi - lo) * (t as f64 / 31.0);
                let (up, um) = analysis.bounds_at(
                    lam,
                    st.xt_theta[j],
                    pre.xty[j],
                    pre.col_norms_sq[j],
                );
                if up.max(um) >= 1.0 {
                    return Err(format!(
                        "feature {j}: removed at lam1 {lam1:.4} but bound {} at \
                         lam {lam:.4} in (lam_s {:.4}, lam1) — removal must be \
                         monotone within the certified interval",
                        up.max(um),
                        rep.lam_s
                    ));
                }
            }
        }
        // ground truth on a descending grid: wherever lam_s certifies
        // removal, the exact solution is zero
        for frac in [0.9, 0.6, 0.35] {
            let lam = frac * lam1;
            if lam <= lam_min {
                continue;
            }
            let (beta, _) = solve_exact(&ds, lam);
            for (j, rep) in reports.iter().enumerate() {
                if rep.lam_s < lam * 0.999 && beta[j].abs() > 1e-8 {
                    return Err(format!(
                        "feature {j}: certified removed above lam_s {:.4} but \
                         beta at lam {lam:.4} is {:e}",
                        rep.lam_s, beta[j]
                    ));
                }
            }
        }
        Ok(())
    });
    assert!(
        removable_total.load(Ordering::Relaxed) > 0,
        "no case produced a removable feature — the property never fired"
    );
}

#[test]
fn prop_sparse_dense_path_parity() {
    // The DesignMatrix abstraction must be storage-transparent: for random
    // sparse datasets, pathwise results — active sets, objective values,
    // and rejection counts per lambda — agree between the CSC backend and
    // its densified twin (objectives to 1e-10, set sizes exactly).
    use sasvi::data::synthetic::SyntheticSpec;
    use sasvi::solver::primal_objective;
    forall(109, 8, 40, 100, |case| {
        let spec = SyntheticSpec {
            n: case.n.max(10),
            p: case.p.max(20),
            nnz: case.nnz.min(case.p),
            density: 0.1,
            ..Default::default()
        };
        let sparse_ds = spec.generate(case.seed);
        if !sparse_ds.x.is_sparse() {
            return Err("generator did not produce CSC".into());
        }
        let mut dense_ds = sparse_ds.clone();
        dense_ds.x = sparse_ds.x.to_dense().into();
        let plan = PathPlan::linear_spaced(&sparse_ds, 8, 0.1);
        let opts = PathOptions {
            cd: CdOptions {
                max_epochs: 20_000,
                tol: 1e-12,
                gap_tol: 1e-12,
                ..Default::default()
            },
            ..Default::default()
        };
        for rule in [RuleKind::Sasvi, RuleKind::Dpp] {
            let rs = run_path_keep_betas(&sparse_ds, &plan, rule, opts);
            let rd = run_path_keep_betas(&dense_ds, &plan, rule, opts);
            let bs = rs.betas.as_ref().unwrap();
            let bd = rd.betas.as_ref().unwrap();
            let mut fit = vec![0.0; sparse_ds.n()];
            for (k, ((ss, sd), lam)) in rs
                .steps
                .iter()
                .zip(rd.steps.iter())
                .zip(plan.lambdas.iter())
                .enumerate()
            {
                if ss.kept != sd.kept || ss.screened != sd.screened {
                    return Err(format!(
                        "{rule:?} step {k}: rejection counts diverged \
                         (sparse {}/{}, dense {}/{})",
                        ss.kept, ss.screened, sd.kept, sd.screened
                    ));
                }
                // identical active sets (support of the solutions)
                for j in 0..sparse_ds.p() {
                    if (bs[k][j] != 0.0) != (bd[k][j] != 0.0)
                        && (bs[k][j] - bd[k][j]).abs() > 1e-10
                    {
                        return Err(format!(
                            "{rule:?} step {k} feature {j}: active-set mismatch \
                             ({} vs {})",
                            bs[k][j], bd[k][j]
                        ));
                    }
                }
                // objective parity to 1e-10 (relative), computed with the
                // same (dense) arithmetic for both solution vectors
                let mut obj = |beta: &[f64]| {
                    dense_ds.x.matvec(beta, &mut fit);
                    let resid: Vec<f64> = dense_ds
                        .y
                        .iter()
                        .zip(fit.iter())
                        .map(|(a, b)| a - b)
                        .collect();
                    primal_objective(&resid, beta, *lam)
                };
                let (os, od) = (obj(&bs[k]), obj(&bd[k]));
                if (os - od).abs() > 1e-10 * (1.0 + os.abs()) {
                    return Err(format!(
                        "{rule:?} step {k}: objective diverged ({os} vs {od})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_working_set_matches_full_solve() {
    // The working-set subsystem's exactness contract: on random dense and
    // 5%-dense CSC problems, working-set solves agree with full unscreened
    // solves to 1e-8 relative objective at every grid point, for both
    // solvers, with and without dynamic screening in the inner solves.
    use sasvi::coordinator::SolverKind;
    use sasvi::data::synthetic::SyntheticSpec;
    use sasvi::screening::dynamic::DynamicOptions;
    use sasvi::solver::primal_objective;
    use sasvi::solver::working_set::WorkingSetOptions;
    forall(111, 6, 36, 90, |case| {
        for density in [1.0f64, 0.05] {
            let ds = SyntheticSpec {
                n: case.n.max(12),
                p: case.p.max(30),
                nnz: case.nnz.max(2),
                density,
                ..Default::default()
            }
            .generate(case.seed);
            if (density < 1.0) != ds.x.is_sparse() {
                return Err("generator picked the wrong backend".into());
            }
            let plan = PathPlan::linear_spaced(&ds, 6, 0.1);
            let cd = CdOptions {
                max_epochs: 20_000,
                tol: 1e-12,
                gap_tol: 1e-12,
                ..Default::default()
            };
            let fista = sasvi::solver::FistaOptions {
                max_iters: 20_000,
                tol: 1e-14,
                lipschitz: None,
            };
            // ground truth: full unscreened solves at every grid point
            let base = run_path_keep_betas(
                &ds,
                &plan,
                RuleKind::None,
                PathOptions { cd, ..Default::default() },
            );
            let b0 = base.betas.as_ref().unwrap();
            let mut fit = vec![0.0; ds.n()];
            let mut obj = |beta: &[f64], lam: f64| {
                ds.x.matvec(beta, &mut fit);
                let resid: Vec<f64> =
                    ds.y.iter().zip(fit.iter()).map(|(a, b)| a - b).collect();
                primal_objective(&resid, beta, lam)
            };
            for solver in [SolverKind::Cd, SolverKind::Fista] {
                for dyn_on in [false, true] {
                    let opts = PathOptions {
                        solver,
                        cd,
                        fista,
                        dynamic: if dyn_on {
                            DynamicOptions::enabled_every(3)
                        } else {
                            DynamicOptions::off()
                        },
                        working_set: WorkingSetOptions::enabled_with_grow(5),
                        ..Default::default()
                    };
                    let r = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, opts);
                    if r.total_ws_outer() == 0 {
                        return Err(format!(
                            "{solver:?} dyn={dyn_on}: no outer iterations — vacuous"
                        ));
                    }
                    let bw = r.betas.as_ref().unwrap();
                    for (k, lam) in plan.lambdas.iter().enumerate() {
                        let o0 = obj(&b0[k], *lam);
                        let ow = obj(&bw[k], *lam);
                        if (ow - o0).abs() > 1e-8 * (1.0 + o0.abs()) {
                            return Err(format!(
                                "{solver:?} dyn={dyn_on} density={density} step {k}: \
                                 objective {ow} vs full {o0}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_io_roundtrip() {
    forall(108, 10, 25, 50, |case| {
        let ds = build_instance(case);
        let dir = std::env::temp_dir().join("sasvi_prop_io");
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        let path = dir.join(format!("ds_{}.bin", case.seed));
        sasvi::data::io::save(&ds, &path).map_err(|e| e.to_string())?;
        let back = sasvi::data::io::load(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        if back.x != ds.x || back.y != ds.y {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}
