//! Concurrent mixed-workload test against the real TCP server: many
//! clients interleave PATH (Lasso) and LPATH (logistic) jobs with STATUS
//! polls and METRICS scrapes on live sockets. Every job must terminate,
//! cache-served replies must be byte-identical to the miss replies that
//! populated the cache, consumed jobs must become unknown, and the pool's
//! status map must be fully drained at the end (`sasvi_pool_status_entries`
//! gauge reads 0).
//!
//! The WATCH battery adds the streaming verb to the mix: several WATCHers
//! on one job race the RESULT consumers that collect (and thereby consume)
//! it. Every watcher must see a terminal event, the stream must never
//! deadlock a RESULT, and a watcher's connection must come back for plain
//! single-reply verbs after its stream closes.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;

use sasvi::server::json::extract_u64;
use sasvi::server::{Server, ServerOptions};

struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let w = TcpStream::connect(addr).unwrap();
        let r = BufReader::new(w.try_clone().unwrap());
        Self { w, r }
    }

    fn roundtrip(&mut self, cmd: &str) -> String {
        writeln!(self.w, "{cmd}").unwrap();
        let mut line = String::new();
        self.r.read_line(&mut line).unwrap();
        line.trim().to_string()
    }
}

/// Read a sample value out of a METRICS reply; the Prometheus text rides
/// inside one-line JSON, so sample lines look like `\nname value\n` with
/// the newlines escaped.
fn metric_value(metrics_reply: &str, name: &str) -> f64 {
    let needle = format!("\\n{name} ");
    let Some(i) = metrics_reply.find(&needle) else {
        return f64::NAN;
    };
    let rest = &metrics_reply[i + needle.len()..];
    let end = rest.find('\\').unwrap_or(rest.len());
    rest[..end].parse().unwrap_or(f64::NAN)
}

#[test]
fn concurrent_mixed_workloads_terminate_bit_identically_and_drain() {
    const CLIENTS: usize = 8;

    let server = Server::bind_with(
        "127.0.0.1:0",
        ServerOptions {
            workers: 2,
            queue_cap: 4,
            cache_cap: 64,
            retain_cap: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_handle();
    let server_thread = std::thread::spawn(move || server.serve().unwrap());

    // dyadic (k, min_frac) pairs: both grids step the frac axis by an
    // exact power of two (1/16 for the Lasso pair, 1/8 for the logistic
    // pair), so the short grid is a bitwise prefix of the long one and
    // the two share cache shards
    let shapes = [
        "PATH 1 sasvi 9 0.5",
        "PATH 1 sasvi 13 0.25",
        "LPATH synthetic100 3 0.01 sasviq 5 0.5",
        "LPATH synthetic100 3 0.01 sasviq 7 0.25",
    ];

    // warm pass: generate the shared dataset, run each shape once (the
    // cache misses), and keep the replies as the canonical answers
    let mut warm = Client::connect(addr);
    let gen = warm.roundtrip("GEN synthetic100 3 0.01");
    assert!(gen.contains("\"dataset\": 1"), "{gen}");
    let canonical: Vec<String> = shapes
        .iter()
        .map(|s| {
            let submitted = warm.roundtrip(s);
            let id = extract_u64(&submitted, "job")
                .unwrap_or_else(|| panic!("no job id for {s}: {submitted}"));
            let reply = warm.roundtrip(&format!("RESULT {id}"));
            assert!(!reply.contains("error"), "warm {s} failed: {reply}");
            reply
        })
        .collect();

    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let shapes = &shapes;
            let canonical = &canonical;
            scope.spawn(move || {
                let mut cl = Client::connect(addr);
                for j in 0..shapes.len() {
                    let i = (j + c) % shapes.len();
                    let submitted = cl.roundtrip(shapes[i]);
                    let id = extract_u64(&submitted, "job")
                        .unwrap_or_else(|| panic!("client {c}: no job id in {submitted}"));

                    // interleave non-job verbs on the same socket while
                    // the job is in flight
                    let status = cl.roundtrip(&format!("STATUS {id}"));
                    assert!(
                        ["queued", "running", "done"].iter().any(|s| status.contains(s)),
                        "client {c}: unexpected status {status}"
                    );
                    let metrics = cl.roundtrip("METRICS");
                    assert!(metrics.contains("sasvi_server_requests_total"));

                    // RESULT blocks until the job terminates: this is the
                    // every-job-terminates assertion, and the reply must
                    // be byte-identical to the canonical (miss) answer
                    let reply = cl.roundtrip(&format!("RESULT {id}"));
                    assert_eq!(
                        reply,
                        canonical[i],
                        "client {c} shape {i}: cache-served reply diverged"
                    );

                    // RESULT consumed the job — it is now unknown
                    let gone = cl.roundtrip(&format!("STATUS {id}"));
                    assert!(
                        gone.contains("error"),
                        "client {c}: consumed job {id} still visible: {gone}"
                    );
                }
            });
        }
    });

    // every terminal entry was observed via RESULT, so the pool's status
    // map must be fully drained — bounded retention left nothing behind
    let metrics = warm.roundtrip("METRICS");
    let entries = metric_value(&metrics, "sasvi_pool_status_entries");
    assert_eq!(entries, 0.0, "status map must drain after every RESULT is collected");

    warm.roundtrip("QUIT");
    stop.store(true, Ordering::Relaxed);
    server_thread.join().unwrap();
}

#[test]
fn watchers_race_result_consumers_and_all_see_a_terminal_event() {
    const WATCHERS: usize = 4;

    // one worker: the heavy job pins it, so the watched job stays queued
    // long enough for every watcher to attach before it can terminate
    let server = Server::bind_with(
        "127.0.0.1:0",
        ServerOptions { workers: 1, queue_cap: 8, retain_cap: 8, ..Default::default() },
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_handle();
    let server_thread = std::thread::spawn(move || server.serve().unwrap());

    let mut main_cl = Client::connect(addr);
    let gen = main_cl.roundtrip("GEN synthetic100 3 0.01");
    assert!(gen.contains("\"dataset\": 1"), "{gen}");
    let heavy = extract_u64(&main_cl.roundtrip("PATH 1 sasvi 60 0.02 dynamic 3"), "job")
        .expect("heavy job id");
    let watched = extract_u64(
        &main_cl.roundtrip("PATH 1 sasvi 7 0.25 dynamic 3 nocache"),
        "job",
    )
    .expect("watched job id");

    std::thread::scope(|scope| {
        for w in 0..WATCHERS {
            scope.spawn(move || {
                let mut cl = Client::connect(addr);
                writeln!(cl.w, "WATCH {watched}").unwrap();
                let mut events = 0usize;
                loop {
                    let mut line = String::new();
                    let nread = cl.r.read_line(&mut line).unwrap();
                    assert!(nread > 0, "watcher {w}: stream closed before terminal");
                    let line = line.trim();
                    assert!(
                        !line.starts_with("{\"error"),
                        "watcher {w}: stream errored: {line}"
                    );
                    events += 1;
                    if line.contains("\"type\":\"terminal\"") {
                        break;
                    }
                }
                assert!(events >= 1, "watcher {w}: empty stream");
                // the connection reverts to one-reply-per-line verbs once
                // the stream closes
                let health = cl.roundtrip("HEALTH");
                assert!(
                    health.contains("\"queue_cap\""),
                    "watcher {w}: connection unusable after stream: {health}"
                );
            });
        }
        // RESULT consumers race the watchers: each blocks until its job
        // terminates, and consuming the watched job must not wedge or
        // error any watcher's stream
        scope.spawn(|| {
            let mut cl = Client::connect(addr);
            let reply = cl.roundtrip(&format!("RESULT {heavy}"));
            assert!(reply.contains("\"kind\""), "heavy RESULT failed: {reply}");
        });
        scope.spawn(|| {
            let mut cl = Client::connect(addr);
            let reply = cl.roundtrip(&format!("RESULT {watched}"));
            assert!(reply.contains("\"kind\""), "watched RESULT failed: {reply}");
        });
    });

    // both RESULTs were collected, so the status map is drained; the
    // watchers were read-only observers and left nothing behind
    let metrics = main_cl.roundtrip("METRICS");
    let entries = metric_value(&metrics, "sasvi_pool_status_entries");
    assert_eq!(entries, 0.0, "WATCH must not retain pool status entries");

    main_cl.roundtrip("QUIT");
    stop.store(true, Ordering::Relaxed);
    server_thread.join().unwrap();
}
