//! The pool's determinism contract, pinned down end to end.
//!
//! `linalg::par` promises: parallel results are *bit-identical* to serial
//! execution at every thread count, on both storage backends. These tests
//! sweep `threads ∈ {1, 2, 4, 8}` over
//!
//!   * the statistics pass `X^T v` (full and active-subset),
//!   * column norms and in-place normalization,
//!   * the dense row-parallel `X beta`,
//!   * all four screening rules' bounds and fused screens,
//!   * the batched Theorem-4 sure-removal analysis,
//!   * a whole screened path run,
//!   * dynamically screened and working-set paths (checkpoint decisions,
//!     prunes, expansions),
//!   * a path with span tracing and the metrics registry live
//!     (observability never perturbs results or event counts),
//!   * a path with a live event-bus subscriber attached at threads 1 and
//!     4 (the streamed step/checkpoint events themselves are identical
//!     on every lane, and the solve stays bit-identical),
//!   * the concurrent-dispatch battery: overlapping `for_blocks` /
//!     `map_blocks` / path solves from many threads through the steal
//!     scheduler, with and without lane leases — the schedule is the one
//!     thing concurrency adds, and no result bit may depend on it,
//!
//! comparing against genuinely serial references (the storage backends'
//! own loops, or the pool pinned to one lane) with `f64::to_bits`
//! equality — not tolerances.

use std::sync::Mutex;

use sasvi::coordinator::{run_path_keep_betas, PathOptions, PathPlan, SolverKind};
use sasvi::data::synthetic::SyntheticSpec;
use sasvi::linalg::{par, DesignMatrix, ThreadPool};
use sasvi::screening::dynamic::DynamicOptions;
use sasvi::screening::sure_removal::SureRemovalAnalysis;
use sasvi::screening::{RuleKind, ScreenContext};
use sasvi::solver::cd::{solve_cd, CdOptions};
use sasvi::solver::DualState;

/// The rule/path tests retune the process-wide thread knob; serialize them
/// so they cannot observe each other's settings.
static THREAD_KNOB: Mutex<()> = Mutex::new(());

const LANES: [usize; 4] = [1, 2, 4, 8];

/// A dense/sparse pair big enough to span many 256-column blocks (with a
/// partial tail block).
fn pair() -> (DesignMatrix, DesignMatrix) {
    let ds = SyntheticSpec {
        n: 60,
        p: 3000,
        nnz: 40,
        density: 0.08,
        ..Default::default()
    }
    .generate(42);
    let sparse = ds.x.clone();
    assert!(sparse.is_sparse());
    let dense: DesignMatrix = sparse.to_dense().into();
    (dense, sparse)
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: index {k}: {x} vs {y}");
    }
}

#[test]
fn t_matvec_bit_identical_across_thread_counts() {
    let (dense, sparse) = pair();
    let n = dense.nrows();
    let p = dense.ncols();
    let v: Vec<f64> = (0..n).map(|i| ((i * 13) % 7) as f64 * 0.31 - 1.0).collect();
    for x in [&dense, &sparse] {
        // serial reference: the backend's own loop, no pool involved
        let mut serial = vec![0.0; p];
        match x {
            DesignMatrix::Dense(m) => m.t_matvec(&v, &mut serial),
            DesignMatrix::Sparse(m) => m.t_matvec(&v, &mut serial),
        }
        for lanes in LANES {
            let pool = ThreadPool::new(lanes);
            let mut out = vec![f64::NAN; p];
            par::t_matvec_with(&pool, lanes, x, &v, &mut out);
            assert_bits_eq(&out, &serial, &format!("t_matvec {} lanes {lanes}", x.storage()));
        }
    }
}

#[test]
fn t_matvec_subset_bit_identical_across_thread_counts() {
    let (dense, sparse) = pair();
    let n = dense.nrows();
    let p = dense.ncols();
    let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).sin()).collect();
    // a scattered, duplicate-free active set
    let idx: Vec<usize> = (0..p).filter(|j| j % 3 == 1).collect();
    for x in [&dense, &sparse] {
        let mut serial = vec![0.0; p];
        match x {
            DesignMatrix::Dense(m) => m.t_matvec_subset(&v, &idx, &mut serial),
            DesignMatrix::Sparse(m) => m.t_matvec_subset(&v, &idx, &mut serial),
        }
        for lanes in LANES {
            let pool = ThreadPool::new(lanes);
            let mut out = vec![0.0; p];
            par::t_matvec_subset_with(&pool, lanes, x, &v, &idx, &mut out);
            assert_bits_eq(
                &out,
                &serial,
                &format!("t_matvec_subset {} lanes {lanes}", x.storage()),
            );
        }
    }
}

#[test]
fn norms_and_normalization_bit_identical_across_thread_counts() {
    let (dense, sparse) = pair();
    for x in [&dense, &sparse] {
        let serial_norms_sq = match x {
            DesignMatrix::Dense(m) => m.col_norms_sq(),
            DesignMatrix::Sparse(m) => m.col_norms_sq(),
        };
        let mut serial_normed = x.clone();
        let serial_norms = match &mut serial_normed {
            DesignMatrix::Dense(m) => m.normalize_columns(),
            DesignMatrix::Sparse(m) => m.normalize_columns(),
        };
        for lanes in LANES {
            let pool = ThreadPool::new(lanes);
            let norms_sq = par::col_norms_sq_with(&pool, lanes, x);
            assert_bits_eq(
                &norms_sq,
                &serial_norms_sq,
                &format!("col_norms_sq {} lanes {lanes}", x.storage()),
            );
            let mut normed = x.clone();
            let norms = par::normalize_columns_with(&pool, lanes, &mut normed);
            assert_bits_eq(
                &norms,
                &serial_norms,
                &format!("normalize norms {} lanes {lanes}", x.storage()),
            );
            assert_eq!(
                normed, serial_normed,
                "normalized matrix diverged ({} lanes {lanes})",
                x.storage()
            );
        }
    }
}

#[test]
fn dense_matvec_bit_identical_across_thread_counts() {
    // row-parallel path needs n to span multiple row blocks
    let ds = SyntheticSpec { n: 4100, p: 50, nnz: 10, ..Default::default() }.generate(5);
    let dense = &ds.x;
    let beta: Vec<f64> = (0..50).map(|j| ((j * 11) % 9) as f64 * 0.4 - 1.6).collect();
    let mut serial = vec![0.0; 4100];
    dense.as_dense().unwrap().matvec(&beta, &mut serial);
    for lanes in LANES {
        let pool = ThreadPool::new(lanes);
        let mut out = vec![f64::NAN; 4100];
        par::matvec_with(&pool, lanes, dense, &beta, &mut out);
        assert_bits_eq(&out, &serial, &format!("dense matvec lanes {lanes}"));
    }
}

/// Solve once to obtain a realistic dual state for rule evaluation.
fn solved_state(ds: &sasvi::data::Dataset, lam1: f64) -> DualState {
    let active: Vec<usize> = (0..ds.p()).collect();
    let norms = ds.x.col_norms_sq();
    let mut beta = vec![0.0; ds.p()];
    let mut resid = ds.y.clone();
    solve_cd(
        &ds.x, &ds.y, lam1, &active, &norms, &mut beta, &mut resid,
        &CdOptions::default(),
    );
    DualState::from_residual(&ds.x, &resid, lam1)
}

#[test]
fn rule_outputs_bit_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let sp = SyntheticSpec {
        n: 50,
        p: 2000,
        nnz: 30,
        density: 0.1,
        ..Default::default()
    }
    .generate(9);
    let mut dn = sp.clone();
    dn.x = sp.x.to_dense().into();
    for ds in [&dn, &sp] {
        let pre = ds.precompute();
        let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
        let lam1 = 0.7 * pre.lambda_max;
        let lam2 = 0.5 * pre.lambda_max;
        let st = solved_state(ds, lam1);
        for rule_kind in [RuleKind::Safe, RuleKind::Dpp, RuleKind::Strong, RuleKind::Sasvi] {
            let rule = rule_kind.build();
            // serial reference: the same code path pinned to one lane
            par::set_threads(1);
            let mut bounds_serial = vec![0.0; ds.p()];
            rule.bounds(&ctx, &st, lam2, &mut bounds_serial);
            let mut keep_serial = vec![false; ds.p()];
            let outcome_serial = rule.screen(&ctx, &st, lam2, &mut keep_serial);
            for lanes in LANES {
                par::set_threads(lanes);
                let mut bounds = vec![f64::NAN; ds.p()];
                rule.bounds(&ctx, &st, lam2, &mut bounds);
                assert_bits_eq(
                    &bounds,
                    &bounds_serial,
                    &format!("{rule_kind:?} bounds {} lanes {lanes}", ds.x.storage()),
                );
                let mut keep = vec![false; ds.p()];
                let outcome = rule.screen(&ctx, &st, lam2, &mut keep);
                assert_eq!(keep, keep_serial, "{rule_kind:?} mask lanes {lanes}");
                assert_eq!(outcome, outcome_serial, "{rule_kind:?} outcome lanes {lanes}");
            }
        }
    }
    par::set_threads(before);
}

#[test]
fn sure_removal_batch_bit_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let ds = SyntheticSpec { n: 40, p: 600, nnz: 12, ..Default::default() }.generate(3);
    let pre = ds.precompute();
    let ctx = ScreenContext::new(&ds.x, &ds.y, &pre);
    let st = solved_state(&ds, 0.6 * pre.lambda_max);
    let analysis = SureRemovalAnalysis::new(&ctx, &st);
    let lam_min = 0.05 * pre.lambda_max;
    par::set_threads(1);
    let serial = analysis.analyze_all(&ctx, &st, lam_min);
    for lanes in LANES {
        par::set_threads(lanes);
        let batch = analysis.analyze_all(&ctx, &st, lam_min);
        for (j, (a, b)) in batch.iter().zip(serial.iter()).enumerate() {
            assert_eq!(a.lam_s.to_bits(), b.lam_s.to_bits(), "lam_s j={j} lanes {lanes}");
            assert_eq!(a.lam_2a.to_bits(), b.lam_2a.to_bits(), "lam_2a j={j}");
            assert_eq!(a.lam_2y.to_bits(), b.lam_2y.to_bits(), "lam_2y j={j}");
            assert_eq!(a.case, b.case, "case j={j}");
        }
    }
    par::set_threads(before);
}

/// Primal objective of a solution vector against a dataset.
fn objective(ds: &sasvi::data::Dataset, beta: &[f64], lam: f64) -> f64 {
    let mut fit = vec![0.0; ds.n()];
    ds.x.matvec(beta, &mut fit);
    let resid: Vec<f64> = ds.y.iter().zip(fit.iter()).map(|(y, f)| y - f).collect();
    sasvi::solver::primal_objective(&resid, beta, lam)
}

/// The dynamic-screening determinism contract: a dynamically screened path
/// is bit-identical at every thread count — the checkpoint decisions
/// (parallel batched bounds, block-ordered reductions) never depend on the
/// schedule — and its final objectives match the static path to 1e-10 on
/// both solvers and both storage backends.
#[test]
fn dynamic_path_bit_identical_and_matches_static_objectives() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let sp = SyntheticSpec {
        n: 50,
        p: 600,
        nnz: 20,
        density: 0.08,
        ..Default::default()
    }
    .generate(19);
    let mut dn = sp.clone();
    dn.x = sp.x.to_dense().into();
    // tight tolerances so both runs land well inside the 1e-10 objective bar
    let cd = CdOptions { max_epochs: 30_000, tol: 1e-12, gap_tol: 1e-12, ..Default::default() };
    let fista = sasvi::solver::FistaOptions { max_iters: 20_000, tol: 1e-14, lipschitz: None };
    for ds in [&dn, &sp] {
        let plan = PathPlan::linear_spaced(ds, 10, 0.2);
        for solver in [SolverKind::Cd, SolverKind::Fista] {
            let opts_dyn = PathOptions {
                solver,
                cd,
                fista,
                dynamic: DynamicOptions::enabled_every(3),
                ..Default::default()
            };
            par::set_threads(1);
            let serial = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts_dyn);
            assert!(
                serial.total_dynamic_dropped() > 0,
                "{solver:?} ({}): dynamic screened nothing — vacuous",
                ds.x.storage()
            );
            for lanes in [2usize, 4, 8] {
                par::set_threads(lanes);
                let parallel = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts_dyn);
                let a = serial.betas.as_ref().unwrap();
                let b = parallel.betas.as_ref().unwrap();
                for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
                    assert_bits_eq(
                        sa,
                        sb,
                        &format!("{solver:?} {} dyn path step {k} lanes {lanes}",
                                 ds.x.storage()),
                    );
                }
                for (s1, s2) in serial.steps.iter().zip(parallel.steps.iter()) {
                    assert_eq!(s1.kept, s2.kept, "kept diverged at lanes {lanes}");
                    assert_eq!(s1.dyn_dropped, s2.dyn_dropped,
                               "dynamic drops diverged at lanes {lanes}");
                    assert_eq!(s1.dyn_rechecks, s2.dyn_rechecks,
                               "checkpoint count diverged at lanes {lanes}");
                    assert_eq!(s1.epochs, s2.epochs,
                               "epoch count diverged at lanes {lanes}");
                }
            }
            // static reference with the same solver tolerances
            par::set_threads(before.max(1));
            let opts_static = PathOptions { solver, cd, fista, ..Default::default() };
            let stat = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts_static);
            let bd = serial.betas.as_ref().unwrap();
            let bs = stat.betas.as_ref().unwrap();
            for (k, lam) in plan.lambdas.iter().enumerate() {
                let od = objective(ds, &bd[k], *lam);
                let os = objective(ds, &bs[k], *lam);
                assert!(
                    (od - os).abs() <= 1e-10 * (1.0 + os.abs()),
                    "{solver:?} ({}): step {k} objective {od} vs static {os}",
                    ds.x.storage()
                );
            }
        }
    }
    par::set_threads(before);
}

/// The working-set determinism contract: outer checkpoints (fused prune +
/// expansion scores) run on the batched engine with block-ordered
/// reductions, and the expansion sort breaks ties by index — so a
/// working-set path is bit-identical at every thread count, on both
/// solvers and both storage backends, and its objectives match the static
/// path to 1e-10.
#[test]
fn working_set_path_bit_identical_and_matches_static_objectives() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let sp = SyntheticSpec {
        n: 50,
        p: 600,
        nnz: 20,
        density: 0.08,
        ..Default::default()
    }
    .generate(23);
    let mut dn = sp.clone();
    dn.x = sp.x.to_dense().into();
    let cd = CdOptions { max_epochs: 30_000, tol: 1e-12, gap_tol: 1e-12, ..Default::default() };
    let fista = sasvi::solver::FistaOptions { max_iters: 20_000, tol: 1e-14, lipschitz: None };
    for ds in [&dn, &sp] {
        let plan = PathPlan::linear_spaced(ds, 10, 0.2);
        for solver in [SolverKind::Cd, SolverKind::Fista] {
            let opts_ws = PathOptions {
                solver,
                cd,
                fista,
                working_set: sasvi::solver::working_set::WorkingSetOptions::enabled_with_grow(7),
                ..Default::default()
            };
            par::set_threads(1);
            let serial = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts_ws);
            assert!(
                serial.total_ws_outer() > 0,
                "{solver:?} ({}): no outer iterations — vacuous",
                ds.x.storage()
            );
            for lanes in [2usize, 4, 8] {
                par::set_threads(lanes);
                let parallel = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts_ws);
                let a = serial.betas.as_ref().unwrap();
                let b = parallel.betas.as_ref().unwrap();
                for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
                    assert_bits_eq(
                        sa,
                        sb,
                        &format!("{solver:?} {} ws path step {k} lanes {lanes}",
                                 ds.x.storage()),
                    );
                }
                let ta = serial.working_set.as_ref().unwrap();
                let tb = parallel.working_set.as_ref().unwrap();
                for (k, (s1, s2)) in serial.steps.iter().zip(parallel.steps.iter()).enumerate() {
                    assert_eq!(s1.kept, s2.kept, "kept diverged at lanes {lanes}");
                    assert_eq!(s1.ws_outer, s2.ws_outer,
                               "outer iterations diverged at lanes {lanes}");
                    assert_eq!(s1.ws_final, s2.ws_final,
                               "final width diverged at lanes {lanes}");
                    assert_eq!(s1.ws_pruned, s2.ws_pruned,
                               "prune count diverged at lanes {lanes}");
                    assert_eq!(s1.epochs, s2.epochs,
                               "epoch count diverged at lanes {lanes}");
                    assert_eq!(ta[k].final_ws, tb[k].final_ws,
                               "working set diverged at step {k} lanes {lanes}");
                }
                assert_eq!(serial.solver_work(), parallel.solver_work(),
                           "work integral diverged at lanes {lanes}");
            }
            // static reference with the same solver tolerances
            par::set_threads(before.max(1));
            let opts_static = PathOptions { solver, cd, fista, ..Default::default() };
            let stat = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts_static);
            let bw = serial.betas.as_ref().unwrap();
            let bs = stat.betas.as_ref().unwrap();
            for (k, lam) in plan.lambdas.iter().enumerate() {
                let ow = objective(ds, &bw[k], *lam);
                let os = objective(ds, &bs[k], *lam);
                assert!(
                    (ow - os).abs() <= 1e-10 * (1.0 + os.abs()),
                    "{solver:?} ({}): step {k} objective {ow} vs static {os}",
                    ds.x.storage()
                );
            }
        }
    }
    par::set_threads(before);
}

/// The logistic-path determinism contract: the §6 pipeline (SasviQ screen,
/// active-set FISTA, gap-safe checkpoints, KKT correction) runs every
/// batched pass on the same block engine, so a logistic path is
/// bit-identical at every thread count on both storage backends.
#[test]
fn logistic_path_bit_identical_across_thread_counts() {
    use sasvi::coordinator::logistic::{run_logistic_path_keep_betas, LogisticPathOptions};
    use sasvi::logistic::{LogiRule, LogisticOptions, LogisticProblem};

    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let sp_ds = SyntheticSpec {
        n: 40,
        p: 600,
        nnz: 20,
        density: 0.05,
        classification: true,
        ..Default::default()
    }
    .generate(29);
    let mut dn_ds = sp_ds.clone();
    dn_ds.x = sp_ds.x.to_dense().into();
    let sp = LogisticProblem::from_labels(&sp_ds).unwrap();
    let dn = LogisticProblem::from_labels(&dn_ds).unwrap();
    for prob in [&dn, &sp] {
        let plan = sasvi::coordinator::PathPlan::linear_from_lambda_max(
            prob.lambda_max(),
            8,
            0.2,
        );
        let opts = LogisticPathOptions {
            solver: LogisticOptions { tol: 1e-12, max_iters: 20_000, ..Default::default() },
            dynamic: DynamicOptions::enabled_every(4),
            ..Default::default()
        };
        par::set_threads(1);
        let serial = run_logistic_path_keep_betas(prob, &plan, LogiRule::SasviQ, opts);
        assert!(
            serial.total_dynamic_dropped() > 0,
            "{}: gap-safe checkpoints idle — vacuous",
            prob.x.storage()
        );
        for lanes in [2usize, 4, 8] {
            par::set_threads(lanes);
            let parallel =
                run_logistic_path_keep_betas(prob, &plan, LogiRule::SasviQ, opts);
            let a = serial.betas.as_ref().unwrap();
            let b = parallel.betas.as_ref().unwrap();
            for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
                assert_bits_eq(
                    sa,
                    sb,
                    &format!("logistic {} path step {k} lanes {lanes}", prob.x.storage()),
                );
            }
            for (s1, s2) in serial.steps.iter().zip(parallel.steps.iter()) {
                assert_eq!(s1.kept, s2.kept, "kept diverged at lanes {lanes}");
                assert_eq!(s1.iters, s2.iters, "iters diverged at lanes {lanes}");
                assert_eq!(
                    s1.dyn_dropped, s2.dyn_dropped,
                    "dynamic drops diverged at lanes {lanes}"
                );
                assert_eq!(
                    s1.dyn_rechecks, s2.dyn_rechecks,
                    "checkpoint count diverged at lanes {lanes}"
                );
                assert_eq!(
                    s1.kkt_violations, s2.kkt_violations,
                    "kkt corrections diverged at lanes {lanes}"
                );
            }
            assert_eq!(
                serial.solver_work(),
                parallel.solver_work(),
                "work integral diverged at lanes {lanes}"
            );
        }
    }
    par::set_threads(before);
}

/// The observability contract: observation never perturbs computation.
/// With span tracing enabled and the metrics registry live, a dynamically
/// screened path still produces bit-identical betas to the untraced
/// serial run at every thread count — and the solver-event metrics
/// (step/checkpoint/epoch counters, gap-histogram bucket counts: exact
/// event counts, not wall-clock) are identical deltas on every lane.
#[test]
fn observability_leaves_results_and_event_counts_bit_identical() {
    use sasvi::obs;

    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let ds = SyntheticSpec {
        n: 50,
        p: 600,
        nnz: 20,
        density: 0.08,
        ..Default::default()
    }
    .generate(19);
    let plan = PathPlan::linear_spaced(&ds, 8, 0.2);
    let opts = PathOptions {
        dynamic: DynamicOptions::enabled_every(3),
        ..Default::default()
    };
    // untraced serial reference (every path-running test in this binary
    // holds THREAD_KNOB, so the metric deltas below are exclusively ours)
    obs::trace::set_enabled(false);
    par::set_threads(1);
    let m0 = obs::metrics::snapshot();
    let reference = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, opts);
    let base = obs::metrics::snapshot().delta_since(&m0);
    assert_eq!(
        base.counters.get("sasvi_path_steps_total").copied().unwrap_or(0),
        plan.len() as u64,
        "every path step lands in the registry"
    );
    assert!(
        base.counters.get("sasvi_checkpoints_total").copied().unwrap_or(0) > 0,
        "dynamic run recorded no checkpoints — vacuous"
    );
    let base_gap = base
        .histograms
        .get("sasvi_checkpoint_gap")
        .cloned()
        .unwrap_or_default();
    assert!(base_gap.count > 0, "no checkpoint gaps observed");
    let tracked = [
        "sasvi_path_steps_total",
        "sasvi_checkpoints_total",
        "sasvi_checkpoint_dropped_total",
        "sasvi_cd_solves_total",
        "sasvi_cd_epochs_total",
        "sasvi_cd_updates_total",
    ];
    obs::trace::set_enabled(true);
    for lanes in LANES {
        par::set_threads(lanes);
        let m1 = obs::metrics::snapshot();
        let traced = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, opts);
        let delta = obs::metrics::snapshot().delta_since(&m1);
        let a = reference.betas.as_ref().unwrap();
        let b = traced.betas.as_ref().unwrap();
        for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
            assert_bits_eq(sa, sb, &format!("traced path step {k} lanes {lanes}"));
        }
        for name in tracked {
            assert_eq!(
                delta.counters.get(name).copied().unwrap_or(0),
                base.counters.get(name).copied().unwrap_or(0),
                "{name} diverged at lanes {lanes}"
            );
        }
        let gap = delta
            .histograms
            .get("sasvi_checkpoint_gap")
            .cloned()
            .unwrap_or_default();
        assert_eq!(
            gap.buckets, base_gap.buckets,
            "gap-histogram buckets diverged at lanes {lanes}"
        );
        assert_eq!(gap.count, base_gap.count, "gap count diverged at lanes {lanes}");
        // the same gap values were observed; only the shard's running f64
        // accumulator differs between sequential runs, so the sum delta
        // matches to rounding rather than bitwise
        assert!(
            (gap.sum - base_gap.sum).abs() <= 1e-9 * (1.0 + base_gap.sum.abs()),
            "gap sum diverged at lanes {lanes}: {} vs {}",
            gap.sum,
            base_gap.sum
        );
    }
    obs::trace::set_enabled(false);
    par::set_threads(before);
}

/// The event-bus half of the observability contract (ISSUE 9): with a
/// live subscriber attached — so every solver publish site actually
/// builds and enqueues its event — a dynamically screened path still
/// produces bit-identical betas to the silent serial reference at
/// threads 1 and 4, and the published step/checkpoint stream itself is
/// deterministic: the same events, with the same payloads, in the same
/// order on every lane. Scheduler `steal` events are the one kind whose
/// count legitimately depends on the lane→block schedule; they are
/// ignored here (the betas assertions already prove they don't leak into
/// results).
#[test]
fn event_subscriber_leaves_betas_and_event_stream_bit_identical() {
    use sasvi::obs::events::{self, EventKind};

    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let ds = SyntheticSpec {
        n: 50,
        p: 600,
        nnz: 20,
        density: 0.08,
        ..Default::default()
    }
    .generate(19);
    let plan = PathPlan::linear_spaced(&ds, 8, 0.2);
    let opts = PathOptions {
        dynamic: DynamicOptions::enabled_every(3),
        ..Default::default()
    };

    // silent serial reference: no subscriber attached, so the publish
    // fast path (one relaxed atomic load) skips every event closure
    par::set_threads(1);
    let reference = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, opts);

    // an event's seq/t_us head is wall-clock; only the payload from
    // "type" onward is part of the determinism contract
    let payload = |ev: &events::Event| -> String {
        let json = ev.to_json();
        let at = json.find("\"type\"").expect("event json has a type field");
        json[at..].to_string()
    };

    let mut first_stream: Option<Vec<String>> = None;
    for lanes in [1usize, 4] {
        par::set_threads(lanes);
        // a queue deep enough that nothing is dropped mid-run — a drop
        // would make the stream-equality assertion depend on timing
        let sub = events::subscribe_filtered(1 << 16, None);
        let observed = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, opts);
        let mut stream = Vec::new();
        let mut steps = 0usize;
        while let Some(ev) = sub.try_recv() {
            match ev.kind {
                EventKind::Step { .. } => {
                    steps += 1;
                    stream.push(payload(&ev));
                }
                EventKind::Checkpoint { .. } => stream.push(payload(&ev)),
                _ => {}
            }
        }
        assert_eq!(sub.dropped(), 0, "subscriber queue overflowed at lanes {lanes}");
        drop(sub);

        let a = reference.betas.as_ref().unwrap();
        let b = observed.betas.as_ref().unwrap();
        for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
            assert_bits_eq(sa, sb, &format!("evented path step {k} lanes {lanes}"));
        }
        assert_eq!(
            steps,
            plan.len(),
            "one step event per grid point at lanes {lanes}"
        );
        assert!(
            stream.len() > steps,
            "dynamic run published no checkpoint events at lanes {lanes}"
        );
        match &first_stream {
            None => first_stream = Some(stream),
            Some(expected) => assert_eq!(
                &stream, expected,
                "step/checkpoint event stream diverged between lanes 1 and {lanes}"
            ),
        }
    }
    par::set_threads(before);
}

/// The steal-scheduler battery (ISSUE 8): several threads issue
/// overlapping `for_blocks` / `map_blocks` dispatches and whole path
/// solves *concurrently* — on one shared explicit pool and on the global
/// one — at every lane count, and every result must be bit-identical to
/// its serial reference. Concurrency adds exactly one degree of freedom,
/// the lane→block schedule (who steals which block from whom), and the
/// contract says no output bit may depend on it: blocks are fixed-size,
/// outputs disjoint or folded in block order.
#[test]
fn concurrent_dispatch_battery_bit_identical_to_serial() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let (dense, sparse) = pair();
    let n = dense.nrows();
    let p = dense.ncols();
    let v: Vec<f64> = (0..n).map(|i| ((i * 11) % 9) as f64 * 0.41 - 1.3).collect();

    // serial references, no pool involved
    let mut ref_dense = vec![0.0; p];
    let mut ref_sparse = vec![0.0; p];
    match &dense {
        DesignMatrix::Dense(m) => m.t_matvec(&v, &mut ref_dense),
        _ => unreachable!(),
    }
    match &sparse {
        DesignMatrix::Sparse(m) => m.t_matvec(&v, &mut ref_sparse),
        _ => unreachable!(),
    }
    let ref_sums: Vec<f64> = (0..p)
        .map(|j| (j as f64 * 0.003).sin())
        .collect::<Vec<f64>>()
        .chunks(par::COL_BLOCK)
        .map(|c| c.iter().sum::<f64>())
        .collect();
    let ds = SyntheticSpec { n: 40, p: 500, nnz: 15, ..Default::default() }.generate(31);
    let plan = PathPlan::linear_spaced(&ds, 6, 0.2);
    par::set_threads(1);
    let ref_path = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, PathOptions::default());

    for lanes in LANES {
        par::set_threads(lanes);
        // an explicit pool shared by all dispatching threads, so their
        // jobs genuinely coexist in one steal registry
        let pool = ThreadPool::new(lanes);
        std::thread::scope(|scope| {
            for rep in 0..2usize {
                // overlapping kernel dispatches on the shared pool
                scope.spawn(|| {
                    for _ in 0..4 {
                        let mut out = vec![f64::NAN; p];
                        par::t_matvec_with(&pool, lanes, &dense, &v, &mut out);
                        assert_bits_eq(&out, &ref_dense, &format!("conc dense lanes {lanes}"));
                    }
                });
                scope.spawn(|| {
                    for _ in 0..4 {
                        let mut out = vec![f64::NAN; p];
                        par::t_matvec_with(&pool, lanes, &sparse, &v, &mut out);
                        assert_bits_eq(&out, &ref_sparse, &format!("conc sparse lanes {lanes}"));
                    }
                });
                // block-ordered fold racing the kernels on the same pool
                scope.spawn(|| {
                    for _ in 0..4 {
                        let sums = pool.map_blocks(p, par::COL_BLOCK, lanes, |_, r| {
                            r.map(|j| (j as f64 * 0.003).sin()).sum::<f64>()
                        });
                        assert_bits_eq(&sums, &ref_sums, &format!("conc fold lanes {lanes}"));
                    }
                });
                // whole path solves on the *global* pool, concurrently with
                // each other and with the explicit-pool traffic above —
                // the multi-job serving scenario
                let (ds, plan, ref_path) = (&ds, &plan, &ref_path);
                scope.spawn(move || {
                    let got =
                        run_path_keep_betas(ds, plan, RuleKind::Sasvi, PathOptions::default());
                    let a = ref_path.betas.as_ref().unwrap();
                    let b = got.betas.as_ref().unwrap();
                    for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
                        assert_bits_eq(
                            sa,
                            sb,
                            &format!("conc path rep {rep} step {k} lanes {lanes}"),
                        );
                    }
                });
                // a lease-capped path solve: the coordinator pool wraps
                // solves in lane budgets, which must never change a bit
                let (ds2, plan2, ref2) = (&ds, &plan, &ref_path);
                scope.spawn(move || {
                    let got = par::with_lane_budget(2, || {
                        run_path_keep_betas(ds2, plan2, RuleKind::Sasvi, PathOptions::default())
                    });
                    let a = ref2.betas.as_ref().unwrap();
                    let b = got.betas.as_ref().unwrap();
                    for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
                        assert_bits_eq(
                            sa,
                            sb,
                            &format!("leased path rep {rep} step {k} lanes {lanes}"),
                        );
                    }
                });
            }
        });
    }
    par::set_threads(before);
}

/// The penalty-generic determinism contract (ISSUE 10): screened paths
/// under every penalty — ℓ1, elastic net, sparse-group lasso, dynamic
/// checkpoints included — are bit-identical at threads 1/2/4/8 on both
/// storage backends. The penalty-native screens and solvers run their
/// batched passes through the same block engine as the ℓ1 pipeline, so
/// the schedule must never reach a result bit.
#[test]
fn penalty_paths_bit_identical_across_thread_counts() {
    use sasvi::penalty::{GroupSpec, Penalty};

    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let sp = SyntheticSpec {
        n: 50,
        p: 600,
        nnz: 20,
        density: 0.08,
        ..Default::default()
    }
    .generate(37);
    let mut dn = sp.clone();
    dn.x = sp.x.to_dense().into();
    for pen in [
        Penalty::L1,
        Penalty::ElasticNet { alpha: 0.3 },
        Penalty::SparseGroupLasso { groups: GroupSpec::new(8), tau: 0.5 },
    ] {
        for ds in [&dn, &sp] {
            let plan = PathPlan::linear_spaced(ds, 10, 0.2);
            let opts = PathOptions {
                dynamic: DynamicOptions::enabled_every(3),
                penalty: pen,
                ..Default::default()
            };
            par::set_threads(1);
            let serial = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts);
            for lanes in [2usize, 4, 8] {
                par::set_threads(lanes);
                let parallel = run_path_keep_betas(ds, &plan, RuleKind::Sasvi, opts);
                let a = serial.betas.as_ref().unwrap();
                let b = parallel.betas.as_ref().unwrap();
                for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
                    assert_bits_eq(
                        sa,
                        sb,
                        &format!(
                            "{} {} path step {k} lanes {lanes}",
                            pen.spec(),
                            ds.x.storage()
                        ),
                    );
                }
                for (s1, s2) in serial.steps.iter().zip(parallel.steps.iter()) {
                    assert_eq!(
                        s1.kept, s2.kept,
                        "{}: kept diverged at lanes {lanes}",
                        pen.spec()
                    );
                    assert_eq!(
                        s1.dyn_dropped, s2.dyn_dropped,
                        "{}: dynamic drops diverged at lanes {lanes}",
                        pen.spec()
                    );
                    assert_eq!(
                        s1.epochs, s2.epochs,
                        "{}: epoch count diverged at lanes {lanes}",
                        pen.spec()
                    );
                }
            }
        }
    }
    par::set_threads(before);
}

#[test]
fn full_screened_path_bit_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let before = par::threads();
    let ds = SyntheticSpec { n: 40, p: 800, nnz: 20, ..Default::default() }.generate(7);
    let plan = PathPlan::linear_spaced(&ds, 12, 0.1);
    par::set_threads(1);
    let serial = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, PathOptions::default());
    for lanes in [2usize, 4, 8] {
        par::set_threads(lanes);
        let parallel = run_path_keep_betas(&ds, &plan, RuleKind::Sasvi, PathOptions::default());
        let a = serial.betas.as_ref().unwrap();
        let b = parallel.betas.as_ref().unwrap();
        for (k, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
            assert_bits_eq(sa, sb, &format!("path step {k} lanes {lanes}"));
        }
        for (s1, s2) in serial.steps.iter().zip(parallel.steps.iter()) {
            assert_eq!(s1.kept, s2.kept, "kept count diverged at lanes {lanes}");
            assert_eq!(s1.nnz, s2.nnz, "nnz diverged at lanes {lanes}");
        }
    }
    par::set_threads(before);
}
