"""L2 screening graphs: Theorem 3 closed forms vs brute-force maximization
over the feasible set Omega, plus rule-dominance and safety properties.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def lasso_cd(x, y, lam, iters=4000, tol=1e-12):
    """High-precision numpy coordinate descent, the ground-truth solver."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    n, p = x.shape
    beta = np.zeros(p)
    resid = y.copy()
    norms = (x * x).sum(axis=0)
    for _ in range(iters):
        delta = 0.0
        for j in range(p):
            if norms[j] <= 0.0:
                continue
            old = beta[j]
            rho = x[:, j] @ resid + norms[j] * old
            new = np.sign(rho) * max(abs(rho) - lam, 0.0) / norms[j]
            if new != old:
                resid -= (new - old) * x[:, j]
                delta = max(delta, abs(new - old))
            beta[j] = new
        if delta < tol:
            break
    return beta, resid


def dual_point(resid, x, lam):
    theta = resid / lam
    infeas = np.abs(x.T @ theta).max()
    if infeas > 1.0:
        theta /= infeas
    return theta


def make_instance(n, p, seed, frac=0.6):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p))
    x /= np.linalg.norm(x, axis=0, keepdims=True) + 1e-12
    beta = np.zeros(p)
    k = max(1, int(0.2 * p))
    beta[rng.choice(p, k, replace=False)] = rng.uniform(-1, 1, k)
    y = x @ beta + 0.05 * rng.standard_normal(n)
    lam_max = np.abs(x.T @ y).max()
    lam1 = frac * lam_max
    return x, y, lam_max, lam1


def screen_inputs(x, y, lam1):
    beta1, resid1 = lasso_cd(x, y, lam1)
    theta1 = dual_point(resid1, x, lam1)
    return beta1, theta1


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("ratio", [0.95, 0.7, 0.4])
def test_theorem3_vs_bruteforce(seed, ratio):
    """u_j^+ from Theorem 3 must match max_{theta in Omega} <x_j, theta>."""
    n, p = 12, 8
    x, y, lam_max, lam1 = make_instance(n, p, seed)
    _, theta1 = screen_inputs(x, y, lam1)
    lam2 = ratio * lam1
    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    tj = jnp.asarray(theta1, jnp.float32)
    lams = jnp.asarray([lam1, lam2], jnp.float32)
    u_plus, u_minus, _ = model.sasvi_screen(xj, yj, tj, lams)
    for j in range(p):
        # The geometric maximizer is exact (up to grid resolution + f32 vs
        # f64); Theorem 3's closed form must agree tightly in both directions.
        bf = float(ref.brute_force_bound(x[:, j], y, theta1, lam1, lam2))
        tol = 2e-3 * max(1.0, abs(bf))
        assert abs(bf - float(u_plus[j])) <= tol, (j, bf, float(u_plus[j]))
        bf_neg = float(ref.brute_force_bound(-x[:, j], y, theta1, lam1, lam2))
        assert abs(bf_neg - float(u_minus[j])) <= tol, (j, bf_neg, float(u_minus[j]))


@pytest.mark.parametrize("seed", [0, 3, 7, 11])
def test_sasvi_safety(seed):
    """Features screened by Sasvi must be zero in a high-precision solution."""
    n, p = 20, 40
    x, y, lam_max, lam1 = make_instance(n, p, seed)
    _, theta1 = screen_inputs(x, y, lam1)
    lam2 = 0.7 * lam1
    beta2, _ = lasso_cd(x, y, lam2)
    u_plus, u_minus, keep = model.sasvi_screen(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(y, jnp.float32),
        jnp.asarray(theta1, jnp.float32),
        jnp.asarray([lam1, lam2], jnp.float32),
    )
    screened = np.asarray(keep) < 0.5
    assert np.all(np.abs(beta2[screened]) < 1e-8)


@pytest.mark.parametrize("seed", [0, 5])
def test_rule_dominance(seed):
    """Sasvi bound <= SAFE and DPP bounds (relaxations of the same VIs)."""
    n, p = 16, 32
    x, y, lam_max, lam1 = make_instance(n, p, seed)
    _, theta1 = screen_inputs(x, y, lam1)
    lam2 = 0.6 * lam1
    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    tj = jnp.asarray(theta1, jnp.float32)
    lams = jnp.asarray([lam1, lam2], jnp.float32)
    up, um, _ = model.sasvi_screen(xj, yj, tj, lams)
    sasvi = np.maximum(np.asarray(up), np.asarray(um))
    safe_b, _, _ = model.safe_screen(xj, yj, tj, lams)
    dpp_b, _, _ = model.dpp_screen(xj, yj, tj, lams)
    assert np.all(sasvi <= np.asarray(safe_b) + 1e-3)
    assert np.all(sasvi <= np.asarray(dpp_b) + 1e-3)


def test_lambda2_to_lambda1_limit():
    """lim_{lam2->lam1} u_j^+ = <x_j, theta1>, u_j^- = -<x_j, theta1>."""
    n, p = 16, 24
    x, y, lam_max, lam1 = make_instance(n, p, 9)
    _, theta1 = screen_inputs(x, y, lam1)
    lam2 = lam1 * (1.0 - 1e-6)
    xj = jnp.asarray(x, jnp.float32)
    tj = jnp.asarray(theta1, jnp.float32)
    up, um, _ = model.sasvi_screen(
        xj, jnp.asarray(y, jnp.float32), tj,
        jnp.asarray([lam1, lam2], jnp.float32),
    )
    xt = np.asarray(x.T @ theta1)
    assert_allclose(np.asarray(up), xt, atol=2e-3)
    assert_allclose(np.asarray(um), -xt, atol=2e-3)


def test_lambda_max_start_case4():
    """At lam1 = lam_max (a=0), Theorem 3 case 4 must apply and stay safe."""
    n, p = 20, 30
    x, y, lam_max, _ = make_instance(n, p, 13)
    theta1 = y / lam_max
    lam2 = 0.8 * lam_max
    beta2, _ = lasso_cd(x, y, lam2)
    up, um, keep = model.sasvi_screen(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(y, jnp.float32),
        jnp.asarray(theta1, jnp.float32),
        jnp.asarray([lam_max, lam2], jnp.float32),
    )
    screened = np.asarray(keep) < 0.5
    assert screened.sum() > 0  # should reject something at this gap
    assert np.all(np.abs(beta2[screened]) < 1e-8)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    ratio=st.floats(min_value=0.3, max_value=0.98),
)
def test_monotone_uplus_hypothesis(seed, ratio):
    """Theorem 4 part 1: u_j^+ decreases as lam2 increases."""
    n, p = 14, 10
    x, y, lam_max, lam1 = make_instance(n, p, seed)
    _, theta1 = screen_inputs(x, y, lam1)
    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    tj = jnp.asarray(theta1, jnp.float32)
    lo = ratio * lam1
    hi = min(lam1 * 0.999, lo * 1.2)
    up_lo, _, _ = model.sasvi_screen(xj, yj, tj, jnp.asarray([lam1, lo], jnp.float32))
    up_hi, _, _ = model.sasvi_screen(xj, yj, tj, jnp.asarray([lam1, hi], jnp.float32))
    # u+ at the larger lam2 (hi) must be <= u+ at the smaller lam2 (lo)
    assert np.all(np.asarray(up_hi) <= np.asarray(up_lo) + 1e-4)
