"""AOT path: every graph lowers to parseable HLO text and the manifest is
consistent with what the Rust runtime expects."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_aot_emits_all_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--shapes", "8:16"],
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    manifest = (out / "manifest.txt").read_text()
    names = [
        "sasvi_screen", "safe_screen", "dpp_screen", "strong_screen",
        "fista_epoch", "lasso_stats", "power_iteration",
    ]
    for name in names:
        art = f"{name}_n8_p16"
        assert f"artifact {art}" in manifest, art
        hlo = (out / f"{art}.hlo.txt").read_text()
        assert "HloModule" in hlo, art
        assert "ENTRY" in hlo, art

    # manifest structure: every artifact block ends with 'end'
    blocks = sum(1 for l in manifest.splitlines() if l.startswith("artifact "))
    ends = sum(1 for l in manifest.splitlines() if l.strip() == "end")
    assert ends == blocks


def test_hlo_text_has_no_serialized_protos(tmp_path):
    # guard against regressions to .serialize(): artifacts must be text
    out = tmp_path / "a"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--shapes", "4:8", "--graphs", "dpp_screen"],
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert r.returncode == 0, r.stderr
    data = (out / "dpp_screen_n4_p8.hlo.txt").read_bytes()
    assert data[:9].isascii()
    text = data.decode()  # must be valid utf-8 text, not a binary proto
    assert text.lstrip().startswith("HloModule")
