"""L2 solver graphs: FISTA epoch vs reference, stats graph, power iteration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make(n, p, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, p)) / np.sqrt(n), jnp.float32)
    y = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    return x, y


def test_fista_epoch_matches_ref():
    n, p, steps = 24, 16, 16
    x, y = make(n, p, 0)
    lam = 0.1 * float(jnp.abs(x.T @ y).max())
    lip = float(model.power_iteration(x, jnp.ones((p,), jnp.float32))[0]) * 1.01
    mask = jnp.ones((p,), jnp.float32)
    beta0 = jnp.zeros((p,), jnp.float32)
    b, z, t, theta = model.fista_epoch(
        x, y, beta0, beta0, jnp.ones((1,), jnp.float32),
        jnp.asarray([lam, lip], jnp.float32), mask, n_steps=steps,
    )
    want = ref.fista_ref(x, y, lam, mask, steps, lip)
    assert_allclose(np.asarray(b), np.asarray(want), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(theta), np.asarray((y - x @ want) / lam),
                    rtol=1e-3, atol=1e-4)


def test_fista_respects_mask():
    n, p = 20, 12
    x, y = make(n, p, 1)
    lam = 0.05 * float(jnp.abs(x.T @ y).max())
    lip = float(model.power_iteration(x, jnp.ones((p,), jnp.float32))[0]) * 1.01
    mask = jnp.asarray([1.0] * 6 + [0.0] * 6, jnp.float32)
    beta0 = jnp.zeros((p,), jnp.float32)
    b, *_ = model.fista_epoch(
        x, y, beta0, beta0, jnp.ones((1,), jnp.float32),
        jnp.asarray([lam, lip], jnp.float32), mask, n_steps=32,
    )
    assert np.all(np.asarray(b)[6:] == 0.0)


def test_fista_converges_orthogonal():
    """On orthonormal X the Lasso solution is the soft-thresholded LS fit."""
    n = 32
    rng = np.random.default_rng(4)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    x = jnp.asarray(q[:, :16], jnp.float32)
    y = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    lam = 0.3
    mask = jnp.ones((16,), jnp.float32)
    beta = jnp.zeros((16,), jnp.float32)
    z, t = beta, jnp.ones((1,), jnp.float32)
    for _ in range(20):
        beta, z, t, theta = model.fista_epoch(
            x, y, beta, z, t, jnp.asarray([lam, 1.01], jnp.float32), mask,
            n_steps=16,
        )
    closed = ref.soft_threshold(x.T @ y, lam)
    assert_allclose(np.asarray(beta), np.asarray(closed), atol=1e-4)


def test_lasso_stats_gap_nonnegative_and_small_at_opt():
    n, p = 24, 16
    x, y = make(n, p, 2)
    lam = 0.4 * float(jnp.abs(x.T @ y).max())
    lip = float(model.power_iteration(x, jnp.ones((p,), jnp.float32))[0]) * 1.01
    mask = jnp.ones((p,), jnp.float32)
    beta = jnp.zeros((p,), jnp.float32)
    z, t = beta, jnp.ones((1,), jnp.float32)
    for _ in range(40):
        beta, z, t, _ = model.fista_epoch(
            x, y, beta, z, t, jnp.asarray([lam, lip], jnp.float32), mask,
            n_steps=16,
        )
    stats = model.lasso_stats(x, y, beta, jnp.asarray([lam], jnp.float32))
    primal, dual, gap, infeas = [float(v) for v in stats]
    assert gap >= -1e-3
    assert gap < 1e-2 * max(1.0, primal)
    assert infeas <= 1.0 + 1e-2


def test_power_iteration_matches_svd():
    x, _ = make(30, 20, 3)
    lip = float(model.power_iteration(x, jnp.ones((20,), jnp.float32))[0])
    want = float(np.linalg.norm(np.asarray(x), 2) ** 2)
    assert abs(lip - want) / want < 1e-2
