"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes/seeds/block sizes; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels import screen as kscreen

jax.config.update("jax_platform_name", "cpu")


def make_problem(n, p, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, p)), dtype)
    y = jnp.asarray(rng.standard_normal((n,)), dtype)
    theta = jnp.asarray(rng.standard_normal((n,)), dtype)
    return x, y, theta


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=96),
    p=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    block_f=st.sampled_from([7, 32, 64, 256]),
)
def test_screen_stats_matches_ref(n, p, seed, block_f):
    x, y, theta = make_problem(n, p, seed)
    got = kscreen.screen_stats(x, theta, y, block_f=block_f)
    want = ref.screen_stats_ref(x, theta, y)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=64),
    p=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    block_f=st.sampled_from([16, 64, 256]),
)
def test_xt_matvec_matches_ref(n, p, seed, block_f):
    x, y, _ = make_problem(n, p, seed)
    got = kscreen.xt_matvec(x, y, block_f=block_f)
    assert_allclose(np.asarray(got), np.asarray(x.T @ y), rtol=2e-4, atol=2e-4)


def test_screen_stats_f64():
    x, y, theta = make_problem(33, 77, 3, dtype=jnp.float32)
    with jax.enable_x64(True):
        x64 = x.astype(jnp.float64)
        y64 = y.astype(jnp.float64)
        t64 = theta.astype(jnp.float64)
        got = kscreen.screen_stats(x64, t64, y64, block_f=32)
        want = ref.screen_stats_ref(x64, t64, y64)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)


def test_block_padding_edge():
    # p smaller than the block, p exactly one block, p one over the block
    for p in (1, 256, 257):
        x, y, theta = make_problem(16, p, p)
        got = kscreen.screen_stats(x, theta, y, block_f=256)
        want = ref.screen_stats_ref(x, theta, y)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-4)


def test_zero_matrix():
    x = jnp.zeros((8, 12), jnp.float32)
    y = jnp.ones((8,), jnp.float32)
    t = jnp.ones((8,), jnp.float32)
    xt, xty, n2 = kscreen.screen_stats(x, t, y)
    assert float(jnp.abs(xt).max()) == 0.0
    assert float(jnp.abs(xty).max()) == 0.0
    assert float(n2.max()) == 0.0
