"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

Run once by `make artifacts`; Python never appears on the request path. The
interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`). The HLO
text parser reassigns ids, so text round-trips cleanly.

Outputs into --out-dir:
  <name>.hlo.txt      one per (graph, shape) pair
  manifest.txt        machine-readable index the Rust runtime parses

Usage: python -m compile.aot --out-dir ../artifacts [--shapes n:p,n:p,...]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32

# (n, p) pairs the Rust runtime may ask for. Kept modest: the end-to-end
# examples and integration tests run on the demo + synthetic shapes; the
# heavyweight Table-1 runs use the pure-Rust screening path (bit-identical,
# cross-checked in rust/tests/runtime_parity.rs).
DEFAULT_SHAPES = [(64, 256), (250, 1000)]


def spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def graph_specs(name, n, p):
    """Example-argument specs for each graph at design-matrix shape (n, p)."""
    x, y, th = spec(n, p), spec(n), spec(n)
    if name.endswith("_screen"):
        return (x, y, th, spec(2))
    if name == "fista_epoch":
        return (x, y, spec(p), spec(p), spec(1), spec(2), spec(p))
    if name == "lasso_stats":
        return (x, y, spec(p), spec(1))
    if name == "power_iteration":
        return (x, spec(p))
    raise KeyError(name)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def fmt_shape(s) -> str:
    return ",".join(str(d) for d in s.shape) if s.shape else "scalar"


def lower_one(name, n, p):
    fn = model.GRAPHS[name]
    specs = graph_specs(name, n, p)
    lowered = jax.jit(fn).lower(*specs)
    return lowered, specs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes",
        default=",".join(f"{n}:{p}" for n, p in DEFAULT_SHAPES),
        help="comma-separated n:p pairs",
    )
    ap.add_argument("--graphs", default=",".join(model.GRAPHS))
    args = ap.parse_args()

    shapes = []
    for tok in args.shapes.split(","):
        n, p = tok.split(":")
        shapes.append((int(n), int(p)))
    names = [g for g in args.graphs.split(",") if g]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = ["# sasvi artifact manifest v1"]
    for n, p in shapes:
        for name in names:
            art = f"{name}_n{n}_p{p}"
            lowered, specs = lower_one(name, n, p)
            text = to_hlo_text(lowered)
            fname = f"{art}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest_lines.append(f"artifact {art}")
            manifest_lines.append(f"graph {name}")
            manifest_lines.append(f"file {fname}")
            manifest_lines.append(f"n {n}")
            manifest_lines.append(f"p {p}")
            for s in specs:
                manifest_lines.append(f"in f32 {fmt_shape(s)}")
            try:
                for info in jax.tree_util.tree_leaves(lowered.out_info):
                    manifest_lines.append(
                        f"out f32 {','.join(str(d) for d in info.shape) or 'scalar'}"
                    )
            except Exception:
                pass
            manifest_lines.append("end")
            print(f"wrote {fname} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(names)} graphs x {len(shapes)} shapes", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
