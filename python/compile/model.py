"""L2: JAX compute graphs for Sasvi Lasso screening and the masked solver.

These are the build-time definitions that `aot.py` lowers to HLO text for the
Rust runtime. Every graph calls the L1 Pallas kernel (`kernels.screen`) for
the per-feature statistics pass, then evaluates the rule's closed form.

All graphs take and return plain f32 arrays with static shapes so the Rust
side can execute them with PJRT literals. Screening decisions are returned as
f32 0/1 masks (PJRT literal marshalling stays dtype-uniform).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels import screen as kscreen

EPS = 1e-12


# ---------------------------------------------------------------------------
# Screening graphs. Signature (shared): (x, y, theta1, lams) where
# lams = [lam1, lam2] packed as a (2,) vector so the artifact has a single
# scalar-block input. Returns (u_plus, u_minus, keep_mask).
# ---------------------------------------------------------------------------

def sasvi_screen(x, y, theta1, lams):
    """Sasvi (Theorem 3) bounds + keep mask. keep=1 means 'cannot discard'."""
    lam1, lam2 = lams[0], lams[1]
    xt_theta1, xty, xnorm2 = kscreen.screen_stats(x, theta1, y)
    u_plus, u_minus = ref.sasvi_bounds_ref(
        xt_theta1, xty, xnorm2, y, theta1, lam1, lam2
    )
    keep = jnp.logical_or(u_plus >= 1.0, u_minus >= 1.0)
    return u_plus, u_minus, keep.astype(x.dtype)


def safe_screen(x, y, theta1, lams):
    """Sequential SAFE bounds + keep mask (same interface as sasvi_screen)."""
    lam2 = lams[1]
    _, xty, xnorm2 = kscreen.screen_stats(x, theta1, y)
    bound = ref.safe_bounds_ref(xty, xnorm2, y, theta1, lam2)
    keep = bound >= 1.0
    return bound, bound, keep.astype(x.dtype)


def dpp_screen(x, y, theta1, lams):
    """Sequential DPP bounds + keep mask."""
    lam1, lam2 = lams[0], lams[1]
    xt_theta1, _, xnorm2 = kscreen.screen_stats(x, theta1, y)
    bound = ref.dpp_bounds_ref(xt_theta1, xnorm2, y, lam1, lam2)
    keep = bound >= 1.0
    return bound, bound, keep.astype(x.dtype)


def strong_screen(x, y, theta1, lams):
    """Strong-rule bounds + keep mask (heuristic; Rust side re-checks KKT)."""
    lam1, lam2 = lams[0], lams[1]
    xt_theta1, _, _ = kscreen.screen_stats(x, theta1, y)
    bound = ref.strong_bounds_ref(xt_theta1, lam1, lam2)
    keep = bound >= 1.0
    return bound, bound, keep.astype(x.dtype)


# ---------------------------------------------------------------------------
# Solver graphs.
# ---------------------------------------------------------------------------

def fista_epoch(x, y, beta, z, tmom, lam_l, mask, n_steps=16):
    """n_steps masked FISTA iterations (one 'epoch'); static unroll via scan.

    Args:
      x: (n, p); y: (n,); beta, z: (p,) current iterate + momentum point;
      tmom: (1,) momentum scalar; lam_l: (2,) = [lambda, lipschitz];
      mask: (p,) 0/1 keep mask from screening.
    Returns (beta', z', tmom', theta') where theta' = (y - X beta')/lambda is
    the scaled dual point the next screening step needs.
    """
    lam, lipschitz = lam_l[0], lam_l[1]
    t = tmom[0]

    def step(carry, _):
        beta_c, z_c, t_c = carry
        resid = x @ z_c - y
        grad = kscreen.xt_matvec(x, resid)
        nxt = ref.soft_threshold(z_c - grad / lipschitz, lam / lipschitz) * mask
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t_c * t_c))
        z_next = nxt + ((t_c - 1.0) / t_next) * (nxt - beta_c)
        return (nxt, z_next, t_next), None

    (beta_o, z_o, t_o), _ = jax.lax.scan(step, (beta, z, t), None, length=n_steps)
    theta = (y - x @ beta_o) / lam
    return beta_o, z_o, t_o.reshape(1), theta


def lasso_stats(x, y, beta, lam_v):
    """Objective, duality gap and infeasibility for a candidate beta.

    Returns a (4,) vector: [primal, dual, gap, max|X^T theta|] where theta is
    the residual scaled into the dual-feasible set.
    """
    lam = lam_v[0]
    resid = x @ beta - y
    primal = 0.5 * jnp.dot(resid, resid) + lam * jnp.sum(jnp.abs(beta))
    theta_raw = -resid / lam
    xt = kscreen.xt_matvec(x, theta_raw)
    infeas = jnp.max(jnp.abs(xt))
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(infeas, EPS))
    theta = theta_raw * scale
    dual = 0.5 * jnp.dot(y, y) - 0.5 * lam * lam * jnp.dot(
        theta - y / lam, theta - y / lam
    )
    gap = primal - dual
    return jnp.stack([primal, dual, gap, infeas])


def power_iteration(x, v0, n_steps=64):
    """Estimate the Lipschitz constant L = ||X||_2^2 by power iteration."""

    def step(v, _):
        w = x.T @ (x @ v)
        nrm = jnp.linalg.norm(w)
        return w / jnp.maximum(nrm, EPS), nrm

    v, nrms = jax.lax.scan(step, v0 / jnp.maximum(jnp.linalg.norm(v0), EPS),
                           None, length=n_steps)
    return nrms[-1].reshape(1)


GRAPHS = {
    "sasvi_screen": sasvi_screen,
    "safe_screen": safe_screen,
    "dpp_screen": dpp_screen,
    "strong_screen": strong_screen,
    "fista_epoch": fista_epoch,
    "lasso_stats": lasso_stats,
    "power_iteration": power_iteration,
}
