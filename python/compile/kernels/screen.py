"""L1 Pallas kernel: fused per-feature screening statistics.

The screening hot-spot of every rule in the paper is the same per-feature
statistics pass over the design matrix X (n x p):

    xt_theta1[j] = <x_j, theta1>        (one column of X^T @ [theta1, y])
    xty[j]       = <x_j, y>
    xnorm2[j]    = ||x_j||^2

On TPU this is a tall-skinny matmul X^T @ [theta1, y] — an MXU-friendly
(p x n)(n x 2) contraction — fused with an elementwise square-reduce, tiled so
each feature block of X makes exactly one HBM->VMEM trip (BlockSpec below).
The paper's hardware was CPU-era MATLAB; DESIGN.md §Hardware-Adaptation
records the mapping. We lower with interpret=True (CPU PJRT cannot execute
Mosaic custom-calls); the BlockSpec schedule is still the real one.

VMEM budget per grid step (f32): n*BF for the X block + 2n resident vectors
+ BF*2 + BF outputs. With n <= 1024 and BF = 256 that is ~1.05 MiB, far under
the ~16 MiB VMEM of a TPU core; BF could grow to 2048 before pressure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_F = 256


def _stats_kernel(x_ref, tv_ref, out_ref, norm_ref):
    """One feature block: x_ref (n, BF), tv_ref (n, 2) = [theta1 | y]."""
    xb = x_ref[...]
    tv = tv_ref[...]
    # (BF, 2) contraction — the MXU matmul on real hardware.
    out_ref[...] = jnp.dot(xb.T, tv, preferred_element_type=out_ref.dtype)
    norm_ref[...] = jnp.sum(xb * xb, axis=0).astype(norm_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def screen_stats(x, theta1, y, *, block_f=DEFAULT_BLOCK_F, interpret=True):
    """Fused per-feature statistics via a Pallas kernel.

    Args:
      x: (n, p) design matrix.
      theta1: (n,) dual point.
      y: (n,) response.
      block_f: feature-block width (grid tile).
      interpret: must stay True off-TPU.

    Returns:
      (xt_theta1, xty, xnorm2), each (p,).
    """
    n, p = x.shape
    bf = min(block_f, max(p, 1))
    p_pad = -(-p // bf) * bf
    if p_pad != p:
        x = jnp.pad(x, ((0, 0), (0, p_pad - p)))
    tv = jnp.stack([theta1, y], axis=1)  # (n, 2)

    grid = (p_pad // bf,)
    out, norm2 = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bf), lambda i: (0, i)),
            pl.BlockSpec((n, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bf, 2), lambda i: (i, 0)),
            pl.BlockSpec((bf,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p_pad, 2), x.dtype),
            jax.ShapeDtypeStruct((p_pad,), x.dtype),
        ],
        interpret=interpret,
    )(x, tv)
    return out[:p, 0], out[:p, 1], norm2[:p]


def _gram_diag_kernel(x_ref, r_ref, out_ref):
    """Fused X^T r for the solver path: one feature block against residual."""
    out_ref[...] = jnp.dot(
        x_ref[...].T, r_ref[...], preferred_element_type=out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def xt_matvec(x, r, *, block_f=DEFAULT_BLOCK_F, interpret=True):
    """X^T @ r with the same feature-block HBM->VMEM schedule as screen_stats.

    Used by the L2 FISTA graph so the gradient's dominant contraction carries
    the explicit tiling (the forward X @ z is a short-fat matvec XLA already
    fuses well).
    """
    n, p = x.shape
    bf = min(block_f, max(p, 1))
    p_pad = -(-p // bf) * bf
    if p_pad != p:
        x = jnp.pad(x, ((0, 0), (0, p_pad - p)))
    r2 = r.reshape(n, 1)
    grid = (p_pad // bf,)
    out = pl.pallas_call(
        _gram_diag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bf), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bf, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p_pad, 1), x.dtype),
        interpret=interpret,
    )(x, r2)
    return out[:p, 0]
