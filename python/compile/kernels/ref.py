"""Pure-jnp oracles for the L1 Pallas kernel and the L2 screening graphs.

Everything in this file is straight-line textbook math, kept deliberately
naive: these are the correctness references the Pallas kernel and the fused
screening graphs are tested against (pytest + hypothesis), and the brute-force
maximizer used to validate Theorem 3's closed forms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def screen_stats_ref(x, theta1, y):
    """Per-feature statistics for screening.

    Args:
      x:      (n, p) design matrix, columns are features.
      theta1: (n,) dual optimal at lambda_1.
      y:      (n,) response.

    Returns:
      xt_theta1: (p,) X^T theta1
      xty:       (p,) X^T y
      xnorm2:    (p,) squared column norms
    """
    xt_theta1 = x.T @ theta1
    xty = x.T @ y
    xnorm2 = jnp.sum(x * x, axis=0)
    return xt_theta1, xty, xnorm2


def sasvi_bounds_ref(xt_theta1, xty, xnorm2, y, theta1, lam1, lam2):
    """Theorem 3 closed-form upper bounds u_j^+ and u_j^-, vectorized.

    Implements all four geometric cases of the theorem:
      a     = y/lam1 - theta1        (scaled prediction X beta_1^* / lam1)
      b     = y/lam2 - theta1 = a + d*y,  d = 1/lam2 - 1/lam1
      case 1: a != 0 and <b,a>/||b|| >  |<x_j,a>|/||x_j||  -> Eq. 26/27
      case 2: <x_j,a> > 0 and <b,a>/||b|| <= <x_j,a>/||x_j|| -> u+ Eq.26, u- Eq.28
      case 3: <x_j,a> < 0 and <b,a>/||b|| <= -<x_j,a>/||x_j|| -> u+ Eq.29, u- Eq.27
      case 4: a == 0 -> Eq. 28 and Eq. 29
    """
    d = 1.0 / lam2 - 1.0 / lam1
    a = y / lam1 - theta1
    anorm2 = jnp.dot(a, a)
    ay = jnp.dot(a, y)
    ynorm2 = jnp.dot(y, y)

    xja = xty / lam1 - xt_theta1              # <x_j, a>
    xjb = xja + d * xty                       # <x_j, b>
    bnorm2 = anorm2 + 2.0 * d * ay + d * d * ynorm2
    ba = anorm2 + d * ay                      # <b, a>
    bnorm = jnp.sqrt(jnp.maximum(bnorm2, 0.0))
    xnorm = jnp.sqrt(jnp.maximum(xnorm2, 0.0))

    a_is_zero = anorm2 <= EPS

    # Projections onto the null space of a (guard a=0; the branch that uses
    # these is only selected when a != 0).
    safe_anorm2 = jnp.where(a_is_zero, 1.0, anorm2)
    xperp2 = jnp.maximum(xnorm2 - xja * xja / safe_anorm2, 0.0)
    yperp2 = jnp.maximum(ynorm2 - ay * ay / safe_anorm2, 0.0)
    xperp_yperp = xty - ay * xja / safe_anorm2
    cross = jnp.sqrt(xperp2 * yperp2)

    u_plus_26 = xt_theta1 + 0.5 * d * (cross + xperp_yperp)
    u_minus_27 = -xt_theta1 + 0.5 * d * (cross - xperp_yperp)
    u_plus_29 = xt_theta1 + 0.5 * (xnorm * bnorm + xjb)
    u_minus_28 = -xt_theta1 + 0.5 * (xnorm * bnorm - xjb)

    # Case selection. "<b,a>/||b|| <= s*<x_j,a>/||x_j||" multiplied through by
    # the (nonnegative) norms to avoid dividing.
    plus_tail = jnp.logical_and(xja < 0.0, ba * xnorm <= -xja * bnorm)
    minus_tail = jnp.logical_and(xja > 0.0, ba * xnorm <= xja * bnorm)
    use_29 = jnp.logical_or(a_is_zero, plus_tail)
    use_28 = jnp.logical_or(a_is_zero, minus_tail)

    u_plus = jnp.where(use_29, u_plus_29, u_plus_26)
    u_minus = jnp.where(use_28, u_minus_28, u_minus_27)
    return u_plus, u_minus


def safe_bounds_ref(xty, xnorm2, y, theta1, lam2):
    """SAFE rule (El Ghaoui et al.), sequential form of Eq. (32)-(33)."""
    tnorm2 = jnp.dot(theta1, theta1)
    ty = jnp.dot(theta1, y)
    s = jnp.clip(ty / (lam2 * jnp.maximum(tnorm2, EPS)), -1.0, 1.0)
    center_diff = s * theta1 - y / lam2
    radius = jnp.sqrt(jnp.maximum(jnp.dot(center_diff, center_diff), 0.0))
    xnorm = jnp.sqrt(jnp.maximum(xnorm2, 0.0))
    bound = jnp.abs(xty) / lam2 + xnorm * radius
    return bound


def dpp_bounds_ref(xt_theta1, xnorm2, y, lam1, lam2):
    """DPP rule (Wang et al.): ball centered at theta1 with radius ||y||(1/l2-1/l1)."""
    ynorm = jnp.sqrt(jnp.maximum(jnp.dot(y, y), 0.0))
    radius = ynorm * (1.0 / lam2 - 1.0 / lam1)
    xnorm = jnp.sqrt(jnp.maximum(xnorm2, 0.0))
    return jnp.abs(xt_theta1) + xnorm * radius


def strong_bounds_ref(xt_theta1, lam1, lam2):
    """Strong rule (Tibshirani et al.), Eq. (31). Heuristic, not safe."""
    ratio = lam1 / lam2
    return ratio * jnp.abs(xt_theta1) + (ratio - 1.0)


def soft_threshold(z, t):
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - t, 0.0)


def fista_ref(x, y, lam, mask, n_steps, lipschitz):
    """Reference masked FISTA for Lasso; identical math to model.fista_epoch."""
    p = x.shape[1]
    beta = jnp.zeros((p,), x.dtype)
    z = beta
    t = jnp.asarray(1.0, x.dtype)

    def step(carry, _):
        beta, z, t = carry
        grad = x.T @ (x @ z - y)
        nxt = soft_threshold(z - grad / lipschitz, lam / lipschitz) * mask
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_next = nxt + ((t - 1.0) / t_next) * (nxt - beta)
        return (nxt, z_next, t_next), None

    (beta, z, t), _ = jax.lax.scan(step, (beta, z, t), None, length=n_steps)
    return beta


def brute_force_bound(xj, y, theta1, lam1, lam2, n_grid=2_000_001, seed=0):
    """Exactly maximize <x_j, theta> over Omega (Eq. 15), independently of
    Theorem 2/3's Lagrangian derivation. Used only in tests.

    Omega = {theta : <theta1 - y/lam1, theta - theta1> >= 0,
                     <theta - y/lam2, theta1 - theta> >= 0}
    i.e. the half-space {<a, theta - theta1> <= 0} (a = y/lam1 - theta1)
    intersected with the ball of center c = (theta1 + y/lam2)/2 and radius
    R = ||y/lam2 - theta1||/2.

    Geometry: for a linear objective over ball-cap, the maximizer lives in
    span{a, x_j} around c. Pick the orthonormal basis e1 = a/||a||,
    e2 = (x_j - <x_j,e1>e1)/||.|| with <x_j, e2> >= 0. Writing
    theta = c + u e1 + v e2, the half-space constraint is
    u <= u_max = -<a, c - theta1>/||a||, and for fixed u the optimal
    v = +sqrt(R^2 - u^2). A fine 1-D grid over u is exact to O(R/n_grid)
    and always *feasible* (an inner approximation), so it can never exceed
    the true maximum.
    """
    import numpy as np

    xj = np.asarray(xj, np.float64)
    y = np.asarray(y, np.float64)
    theta1 = np.asarray(theta1, np.float64)
    a = y / lam1 - theta1
    c = 0.5 * (theta1 + y / lam2)
    rad = 0.5 * np.linalg.norm(y / lam2 - theta1)
    anorm = np.linalg.norm(a)
    if anorm < 1e-14:
        # ball only: closed ball maximum (still independent of Thm 3's cases)
        return float(xj @ c + rad * np.linalg.norm(xj))
    e1 = a / anorm
    x_par = xj @ e1
    x_perp_vec = xj - x_par * e1
    x_perp = np.linalg.norm(x_perp_vec)
    u_max = min(rad, -(a @ (c - theta1)) / anorm)
    u = np.linspace(-rad, u_max, n_grid)
    v = np.sqrt(np.maximum(rad * rad - u * u, 0.0))
    vals = xj @ c + u * x_par + v * x_perp
    return float(vals.max())
